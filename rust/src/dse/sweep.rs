//! Zero-rebuild, parallel DSE sweep engine.
//!
//! The seed exploration loop paid O(points × tasks) redundant work: every
//! enumerated co-design rebuilt the dependence graph and elaborated
//! program from scratch (`sim::estimate` → `DepGraph::build` +
//! `ElabProgram::build`), re-ran the HLS cost model for every
//! (kernel, unroll) it touched, and evaluated points one after another.
//! CEDR (Mack et al., 2022) and the hardware-HEFT scheduler work (Fusco et
//! al., 2022) both separate one-time program analysis from
//! per-configuration scheduling; [`SweepContext`] is that separation here:
//!
//! * the [`DepGraph`] and [`ElabProgram`] are built **once** per program
//!   and shared (immutably) by every evaluation;
//! * HLS reports are memoized per `(kernel, unroll)` — [`SweepContext::prime`]
//!   fills the cache for a [`DseSpace`] up front so a sweep performs zero
//!   duplicate cost-model calls;
//! * point evaluation shards across `std::thread::scope` workers (keeping
//!   the repository's zero-external-dependency style). Each worker keeps
//!   one [`Simulator`] alive and [`Simulator::reset`]s it per point, so the
//!   event heap, ready queues and predecessor counters are allocated once
//!   per worker, not once per point, and segment recording is disabled
//!   because ranking needs only makespan + busy accounting.
//!
//! Determinism: candidates are evaluated under a work-stealing index
//! cursor, results are keyed by candidate index and merged in enumeration
//! order, and the final ranking uses the same stable sort as the serial
//! path — so `explore` returns a bit-identical `Vec<DsePoint>` for any
//! worker count (asserted by `rust/tests/sweep_determinism.rs`).

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::config::{BoardConfig, CoDesign};
use crate::coordinator::deps::DepGraph;
use crate::coordinator::elaborate::ElabProgram;
use crate::coordinator::sched::Policy;
use crate::coordinator::task::{KernelId, TaskProgram};
use crate::hls::{CostModel, FpgaPart, HlsReport, Resources};
use crate::power::PowerModel;
use crate::sim::engine::{AccelInstance, Simulator};
use crate::sim::{EstimatorModel, SimResult};
use crate::util::fxhash::FxHashMap;

use super::{describe, DsePoint, DseSpace, Objective};

/// Number of evaluation workers to use by default: one per available core.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Work-stealing indexed parallel map — the one scoped-thread loop every
/// parallel stage of the DSE layer shares (point evaluation, suite
/// evaluation, bound computation, pruned rounds).
///
/// Item indices `0..n_items` are claimed through a shared atomic cursor;
/// `f` runs with the claiming worker's mutable slot (per-worker state such
/// as a reusable simulator); every `Some` result is collected **unordered**
/// — callers key results by index and sort, which is what keeps their
/// output independent of the worker count.
pub(crate) fn parallel_for_indexed<S, R, F>(slots: &mut [S], n_items: usize, f: F) -> Vec<R>
where
    S: Send,
    R: Send,
    F: Fn(&mut S, usize) -> Option<R> + Sync,
{
    debug_assert!(!slots.is_empty() || n_items == 0);
    let cursor = AtomicUsize::new(0);
    let mut out: Vec<R> = Vec::with_capacity(n_items);
    std::thread::scope(|s| {
        let handles: Vec<_> = slots
            .iter_mut()
            .map(|slot| {
                let f = &f;
                let cursor = &cursor;
                s.spawn(move || {
                    let mut acc: Vec<R> = Vec::new();
                    loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= n_items {
                            break;
                        }
                        if let Some(r) = f(slot, i) {
                            acc.push(r);
                        }
                    }
                    acc
                })
            })
            .collect();
        for h in handles {
            out.extend(h.join().expect("parallel worker panicked"));
        }
    });
    out
}

/// [`parallel_for_indexed`] with **panic isolation**: every call to `f`
/// runs under `catch_unwind`, so one poisoned item can never tear down the
/// worker pool or lose the results of its siblings. On a panic the
/// worker's slot is passed through `reset` (worker state that unwound
/// mid-simulation must be rebuilt, not reused) and the item's index is
/// recorded. Returns the unordered results plus the poisoned indices in
/// ascending order — which items poison depends only on the items
/// themselves, never on worker scheduling, so callers stay bit-identical
/// for any worker count. (The default panic hook still prints each
/// poisoned point's message to stderr — deliberate: a poisoned point is a
/// bug report, not something to swallow silently.)
pub(crate) fn parallel_for_indexed_isolated<S, R, F, G>(
    slots: &mut [S],
    n_items: usize,
    f: F,
    reset: G,
) -> (Vec<R>, Vec<usize>)
where
    S: Send,
    R: Send,
    F: Fn(&mut S, usize) -> Option<R> + Sync,
    G: Fn(&mut S) + Sync,
{
    debug_assert!(!slots.is_empty() || n_items == 0);
    let cursor = AtomicUsize::new(0);
    let mut out: Vec<R> = Vec::with_capacity(n_items);
    let mut poisoned: Vec<usize> = Vec::new();
    std::thread::scope(|s| {
        let handles: Vec<_> = slots
            .iter_mut()
            .map(|slot| {
                let f = &f;
                let reset = &reset;
                let cursor = &cursor;
                s.spawn(move || {
                    let mut acc: Vec<R> = Vec::new();
                    let mut poison: Vec<usize> = Vec::new();
                    loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= n_items {
                            break;
                        }
                        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                            || f(&mut *slot, i),
                        ));
                        match run {
                            Ok(Some(r)) => acc.push(r),
                            Ok(None) => {}
                            Err(_) => {
                                reset(&mut *slot);
                                poison.push(i);
                            }
                        }
                    }
                    (acc, poison)
                })
            })
            .collect();
        for h in handles {
            let (acc, poison) = h.join().expect("isolated worker cannot itself panic");
            out.extend(acc);
            poisoned.extend(poison);
        }
    });
    poisoned.sort_unstable();
    (out, poisoned)
}

/// Shared, immutable evaluation context for one (program, board, part)
/// triple: dependence graph, elaborated program and memoized HLS reports.
/// Build it once, then run any number of enumerations / explorations /
/// single-point estimates against it.
pub struct SweepContext<'p> {
    /// The program under exploration.
    pub program: &'p TaskProgram,
    /// Platform description shared by every evaluation.
    pub board: &'p BoardConfig,
    /// FPGA part the co-designs must fit.
    pub part: FpgaPart,
    /// One-time dependence graph (shared by bounds and simulation).
    pub graph: DepGraph,
    /// One-time elaborated program (creation chain + transfer footprints).
    pub elab: ElabProgram,
    cost: CostModel,
    power: PowerModel,
    /// Memoized `(kernel, unroll) → HlsReport`.
    reports: FxHashMap<(KernelId, u32), HlsReport>,
    /// Reports served from the level-1 kernel sub-memo by
    /// [`SweepContext::prime_with_memo`] instead of the cost model
    /// (surfaced as [`PruneStats::kernel_hits`](super::PruneStats) by the
    /// warm sweeps).
    kernel_memo_hits: usize,
}

impl<'p> SweepContext<'p> {
    /// Build the one-time program analysis (graph + elaboration). The HLS
    /// cache starts empty; call [`SweepContext::prime`] with the space you
    /// are about to sweep.
    pub fn new(program: &'p TaskProgram, board: &'p BoardConfig, part: FpgaPart) -> Self {
        let graph = DepGraph::build(program);
        let elab = ElabProgram::build(program, &graph);
        SweepContext {
            program,
            board,
            part,
            graph,
            elab,
            cost: CostModel::from_board(board),
            power: PowerModel::default(),
            reports: FxHashMap::default(),
            kernel_memo_hits: 0,
        }
    }

    /// Convenience constructor: build and prime for `space` in one step.
    pub fn for_space(
        program: &'p TaskProgram,
        board: &'p BoardConfig,
        part: &FpgaPart,
        space: &DseSpace,
    ) -> Self {
        let mut ctx = Self::new(program, board, part.clone());
        ctx.prime(space);
        ctx
    }

    /// [`SweepContext::for_space`] with the HLS cache primed from the
    /// level-1 kernel sub-memo first (see
    /// [`SweepContext::prime_with_memo`]).
    pub fn for_space_warm(
        program: &'p TaskProgram,
        board: &'p BoardConfig,
        part: &FpgaPart,
        space: &DseSpace,
        memo: &super::warm::EvalMemo,
    ) -> Self {
        let mut ctx = Self::new(program, board, part.clone());
        ctx.prime_with_memo(space, memo);
        ctx
    }

    /// Memoize the HLS report of every `(kernel, unroll)` pair the space
    /// can touch, so the sweep itself performs zero cost-model calls.
    pub fn prime(&mut self, space: &DseSpace) {
        for ks in &space.kernels {
            let Some(kid) = self.program.kernel_id(&ks.kernel) else {
                continue;
            };
            for &u in &ks.unrolls {
                if self.reports.contains_key(&(kid, u)) {
                    continue;
                }
                let r = self
                    .cost
                    .estimate(&ks.kernel, &self.program.kernel(kid).profile, u);
                self.reports.insert((kid, u), r);
            }
        }
    }

    /// Like [`SweepContext::prime`], but every `(kernel, unroll)` pair is
    /// first looked up in the level-1 kernel sub-memo of an
    /// [`EvalMemo`](super::EvalMemo): on a hit the stored report — exact
    /// by construction, since the level-1 key covers the kernel profile
    /// and both board-derived cost-model constants — fills the cache
    /// without a cost-model call, and only the misses run the model. This
    /// is the cross-size (and cross-run) warm start: two problem sizes of
    /// a blocked app share kernel profiles, so the second size primes
    /// entirely from the memo recorded at the first. Returns the number of
    /// memo-served reports (also surfaced as
    /// [`PruneStats::kernel_hits`](super::PruneStats) by the warm sweeps).
    pub fn prime_with_memo(&mut self, space: &DseSpace, memo: &super::warm::EvalMemo) -> usize {
        let mut hits = 0usize;
        for ks in &space.kernels {
            let Some(kid) = self.program.kernel_id(&ks.kernel) else {
                continue;
            };
            let kfp = crate::hls::kernel_fingerprint(&ks.kernel, &self.program.kernel(kid).profile);
            for &u in &ks.unrolls {
                if self.reports.contains_key(&(kid, u)) {
                    continue;
                }
                let r = match memo.lookup_report(
                    kfp,
                    u,
                    self.board.fabric_freq_mhz,
                    self.board.dma_bw_mbps,
                ) {
                    Some(report) => {
                        hits += 1;
                        report.clone()
                    }
                    None => self
                        .cost
                        .estimate(&ks.kernel, &self.program.kernel(kid).profile, u),
                };
                self.reports.insert((kid, u), r);
            }
        }
        self.kernel_memo_hits += hits;
        hits
    }

    /// Number of memoized HLS reports (bench/diagnostic).
    pub fn cached_reports(&self) -> usize {
        self.reports.len()
    }

    /// Reports served from the kernel sub-memo so far (see
    /// [`SweepContext::prime_with_memo`]).
    pub fn kernel_memo_hits(&self) -> usize {
        self.kernel_memo_hits
    }

    /// The power model shared by every point evaluation (the energy lower
    /// bound of `dse::prune` must use the exact same constants).
    pub(crate) fn power_model(&self) -> &PowerModel {
        &self.power
    }

    /// The HLS report for a variant: cache hit, or an on-the-fly estimate
    /// for variants outside the primed space (same numbers either way —
    /// the cost model is deterministic).
    pub fn report_for(&self, kid: KernelId, kernel: &str, unroll: u32) -> HlsReport {
        match self.reports.get(&(kid, unroll)) {
            Some(r) => r.clone(),
            None => self
                .cost
                .estimate(kernel, &self.program.kernel(kid).profile, unroll),
        }
    }

    /// Resource vector only (avoids cloning the report's strings on hit).
    pub fn resources_for(&self, kid: KernelId, kernel: &str, unroll: u32) -> Resources {
        match self.reports.get(&(kid, unroll)) {
            Some(r) => r.resources,
            None => {
                self.cost
                    .estimate(kernel, &self.program.kernel(kid).profile, unroll)
                    .resources
            }
        }
    }

    /// Resolve a co-design against the program using the memoized reports —
    /// the cached equivalent of [`crate::sim::resolve_codesign`], with the
    /// same feasibility checks and error conditions.
    pub fn resolve(&self, codesign: &CoDesign) -> anyhow::Result<(Vec<AccelInstance>, Vec<bool>)> {
        let mut accels = Vec::with_capacity(codesign.accels.len());
        for spec in &codesign.accels {
            let kid = self.program.kernel_id(&spec.kernel).ok_or_else(|| {
                anyhow::anyhow!("co-design accel '{}' not in program", spec.kernel)
            })?;
            if !self.program.kernel(kid).targets.fpga {
                anyhow::bail!(
                    "kernel '{}' is not annotated with target device(fpga)",
                    spec.kernel
                );
            }
            accels.push(AccelInstance {
                kernel: kid,
                report: self.report_for(kid, &spec.kernel, spec.unroll),
            });
        }
        let resources: Vec<Resources> = accels.iter().map(|a| a.report.resources).collect();
        if !self.part.fits(&resources) {
            anyhow::bail!(
                "co-design '{}' does not fit {} (utilization {:.0}%)",
                codesign.name,
                self.part.name,
                self.part.utilization(&resources) * 100.0
            );
        }
        let mut smp_eligible = Vec::with_capacity(self.program.kernels.len());
        for (kid, k) in self.program.kernels.iter().enumerate() {
            let has_accel = accels.iter().any(|a| a.kernel as usize == kid);
            let eligible = if has_accel {
                k.targets.smp && codesign.allows_smp(&k.name)
            } else {
                k.targets.smp
            };
            if !eligible && !has_accel {
                anyhow::bail!(
                    "kernel '{}' can run nowhere under co-design '{}'",
                    k.name,
                    codesign.name
                );
            }
            smp_eligible.push(eligible);
        }
        Ok((accels, smp_eligible))
    }

    /// One-shot coarse-grain estimate of a co-design against the shared
    /// context — equals `sim::estimate` on the same inputs, without
    /// rebuilding the graph/elaboration. For many points, prefer
    /// [`SweepContext::worker`] which also reuses the simulator buffers.
    pub fn estimate(&self, codesign: &CoDesign) -> anyhow::Result<SimResult> {
        let (accels, smp) = self.resolve(codesign)?;
        let mut sim = Simulator::new(
            self.program,
            &self.elab,
            self.board,
            &accels,
            &smp,
            Policy::Greedy,
        );
        let mut model = EstimatorModel::new(self.board);
        Ok(sim.run_mut(&mut model))
    }

    /// Enumerate feasible co-designs over the space (resource-pruned),
    /// identical to the seed `dse::enumerate` but with every resource
    /// vector served from the memoized reports. With `space.mixed`, a
    /// kernel's per-option accelerator multiset may mix unroll variants
    /// (see [`DseSpace::mixed`](super::DseSpace)); the homogeneous path is
    /// byte-identical to the historical enumeration.
    pub fn enumerate(&self, space: &DseSpace) -> Vec<CoDesign> {
        // Per-kernel options: (accel list, smp flag), parallel to the
        // surviving KernelSpace entries.
        let mut per_kernel: Vec<Vec<(Vec<(String, u32)>, bool)>> = Vec::new();
        let mut kspaces: Vec<&super::KernelSpace> = Vec::new();
        for ks in &space.kernels {
            let Some(kid) = self.program.kernel_id(&ks.kernel) else {
                continue;
            };
            // Variants that fit the part alone (a multiset containing an
            // infeasible-alone variant cannot fit either).
            let feasible: Vec<u32> = ks
                .unrolls
                .iter()
                .copied()
                .filter(|&u| self.part.fits(&[self.resources_for(kid, &ks.kernel, u)]))
                .collect();
            let mut opts: Vec<(Vec<(String, u32)>, bool)> = vec![(Vec::new(), false)];
            let multisets =
                super::variant_multisets(feasible.len(), ks.max_instances, space.mixed);
            for multiset in multisets {
                let accels: Vec<(String, u32)> = multiset
                    .iter()
                    .map(|&vi| (ks.kernel.clone(), feasible[vi]))
                    .collect();
                opts.push((accels.clone(), false));
                if ks.try_smp {
                    opts.push((accels, true));
                }
            }
            per_kernel.push(opts);
            kspaces.push(ks);
        }

        // Cartesian product with feasibility pruning.
        let mut out = Vec::new();
        let mut idx = vec![0usize; per_kernel.len()];
        let mut resources: Vec<Resources> = Vec::new();
        loop {
            // Assemble the candidate.
            let mut cd = CoDesign::new("dse");
            for (ki, &i) in idx.iter().enumerate() {
                let (accels, smp) = &per_kernel[ki][i];
                for (k, u) in accels {
                    cd = cd.with_accel(k, *u);
                }
                if *smp {
                    cd = cd.with_smp(&kspaces[ki].kernel);
                }
            }
            // Feasibility: total resources fit.
            resources.clear();
            for a in &cd.accels {
                let kid = self.program.kernel_id(&a.kernel).unwrap();
                resources.push(self.resources_for(kid, &a.kernel, a.unroll));
            }
            if self.part.fits(&resources) {
                cd.name = describe(&cd);
                out.push(cd);
            }
            // Advance the odometer.
            let mut carry = true;
            for (ki, i) in idx.iter_mut().enumerate() {
                if !carry {
                    break;
                }
                *i += 1;
                if *i < per_kernel[ki].len() {
                    carry = false;
                } else {
                    *i = 0;
                }
            }
            if carry {
                break;
            }
        }
        out
    }

    /// A reusable evaluation worker: one simulator + one timing model,
    /// reset per point. Create one per thread.
    pub fn worker(&self) -> SweepWorker<'_, 'p> {
        let mut sim = Simulator::new(
            self.program,
            &self.elab,
            self.board,
            &[],
            &[],
            Policy::Greedy,
        );
        // Ranking needs only makespan + busy accounting.
        sim.set_record_segments(false);
        SweepWorker {
            ctx: self,
            sim,
            model: EstimatorModel::new(self.board),
        }
    }

    /// Turn a finished simulation into a ranked design point.
    fn point_from(&self, codesign: &CoDesign, res: &SimResult) -> DsePoint {
        let resources: Vec<Resources> = codesign
            .accels
            .iter()
            .map(|a| {
                let kid = self.program.kernel_id(&a.kernel).unwrap();
                self.resources_for(kid, &a.kernel, a.unroll)
            })
            .collect();
        let util = self.part.utilization(&resources);
        let energy = self
            .power
            .energy(res, &resources, util, self.board.fabric_freq_mhz);
        DsePoint {
            codesign: codesign.clone(),
            est_ms: res.makespan_ms(),
            energy_j: energy.total_j(),
            edp: energy.edp(),
            fabric_util: util,
        }
    }

    /// Evaluate a candidate list across `workers` threads with
    /// deterministic (enumeration-order) output. Points whose co-design
    /// cannot run (some kernel has nowhere to execute) are skipped, as in
    /// the serial path; a point whose evaluation *panics* is poisoned and
    /// skipped too (isolation — one bad point never tears down the pool),
    /// identically for any worker count.
    pub fn evaluate_all(&self, cands: &[CoDesign], workers: usize) -> Vec<DsePoint> {
        let n = cands.len();
        let workers = workers.clamp(1, n.max(1));
        // One lazily-built worker (simulator + model) per thread; a
        // poisoned worker is dropped and lazily rebuilt.
        let mut slots: Vec<Option<SweepWorker<'_, 'p>>> = (0..workers).map(|_| None).collect();
        let (mut indexed, _poisoned) = parallel_for_indexed_isolated(
            &mut slots,
            n,
            |slot, i| {
                let w = slot.get_or_insert_with(|| self.worker());
                w.evaluate(&cands[i]).map(|p| (i, p))
            },
            |slot| *slot = None,
        );
        // Restore enumeration order so ranking ties break exactly like the
        // serial path (the score sort below is stable).
        indexed.sort_unstable_by_key(|e| e.0);
        indexed.into_iter().map(|(_, p)| p).collect()
    }

    /// Enumerate + evaluate + rank. Bit-identical output for any worker
    /// count, including `workers == 1`.
    ///
    /// # Example
    ///
    /// ```
    /// use zynq_estimator::apps::matmul::Matmul;
    /// use zynq_estimator::config::BoardConfig;
    /// use zynq_estimator::dse::{DseSpace, Objective, SweepContext};
    /// use zynq_estimator::hls::FpgaPart;
    ///
    /// let board = BoardConfig::zynq706();
    /// let program = Matmul::new(256, 64).build_program(&board);
    /// let space = DseSpace::from_program(&program);
    /// let ctx = SweepContext::for_space(&program, &board, &FpgaPart::xc7z045(), &space);
    /// let points = ctx.explore(&space, Objective::Time, 2);
    /// assert!(!points.is_empty());
    /// // The ranking is sorted by the objective...
    /// assert!(points.windows(2).all(|w| w[0].est_ms <= w[1].est_ms));
    /// // ...and is bit-identical for any worker count.
    /// let serial = ctx.explore(&space, Objective::Time, 1);
    /// assert_eq!(serial.len(), points.len());
    /// assert!(serial
    ///     .iter()
    ///     .zip(&points)
    ///     .all(|(a, b)| a.est_ms.to_bits() == b.est_ms.to_bits()));
    /// ```
    pub fn explore(
        &self,
        space: &DseSpace,
        objective: Objective,
        workers: usize,
    ) -> Vec<DsePoint> {
        let cands = self.enumerate(space);
        let mut points = self.evaluate_all(&cands, workers);
        points.sort_by(|a, b| a.score(objective).partial_cmp(&b.score(objective)).unwrap());
        points
    }

    /// Like [`SweepContext::explore`], but with the bound-guided pruned
    /// enumeration of [`dse::prune`](super::prune): infeasible odometer
    /// subtrees, dominated unroll variants and bound-dominated candidates
    /// are cut *before* simulation. The returned ranking contains only the
    /// evaluated points, is bit-identical for any worker count, and its
    /// best point and time-energy Pareto front equal the exhaustive
    /// sweep's (see the prune module docs for the guarantee).
    pub fn explore_pruned(
        &self,
        space: &DseSpace,
        objective: Objective,
        workers: usize,
    ) -> (Vec<DsePoint>, super::prune::PruneStats) {
        super::prune::explore_pruned_multi(&[(self, space)], objective, workers)
            .pop()
            .expect("one input yields one output")
    }

    /// [`SweepContext::explore_pruned`] with an explicit candidate
    /// [`OrderMode`](super::OrderMode) for the bound-guided rounds.
    /// Ordering only changes *when* candidates are considered (hence how
    /// early the incumbent tightens and how many points get simulated);
    /// every mode keeps the losslessness contract — identical best point
    /// and time-energy Pareto front — and is bit-identical for any worker
    /// count. `OrderMode::BoundAsc` reproduces `explore_pruned` exactly.
    pub fn explore_pruned_with(
        &self,
        space: &DseSpace,
        objective: Objective,
        workers: usize,
        order: super::prune::OrderMode,
    ) -> (Vec<DsePoint>, super::prune::PruneStats) {
        super::prune::explore_pruned_warm(self, space, None, order, objective, workers)
    }

    /// Warm-started pruned exploration against a persistent
    /// [`EvalMemo`](super::EvalMemo): candidates whose exact
    /// `(program, board, part, co-design)` evaluation is already memoized
    /// are returned without re-simulation (bit-identical by construction —
    /// the memo key fingerprints everything the evaluation depends on) and
    /// seed the bound frontier, so the remaining candidates start cutting
    /// against a warm incumbent. Newly evaluated points are recorded back
    /// into the memo. Same losslessness and any-worker-count determinism
    /// guarantees as [`SweepContext::explore_pruned`];
    /// [`PruneStats::memo_hits`](super::PruneStats) and
    /// [`PruneStats::seeded_cut`](super::PruneStats) account for the warm
    /// state.
    pub fn explore_warm(
        &self,
        space: &DseSpace,
        memo: &mut super::warm::EvalMemo,
        objective: Objective,
        workers: usize,
        order: super::prune::OrderMode,
    ) -> (Vec<DsePoint>, super::prune::PruneStats) {
        super::prune::explore_pruned_warm(self, space, Some(memo), order, objective, workers)
    }

    /// [`SweepContext::explore_warm`] with crash recovery through a
    /// [`RecoverySession`](super::RecoverySession): every committed round
    /// of fresh evaluations is journaled to the memo's `.wal` sidecar and
    /// the candidate order is checkpointed to `.ckpt`, so an interrupted
    /// sweep resumed from
    /// [`EvalMemo::load_with_recovery`](super::warm::EvalMemo::load_with_recovery)
    /// finishes with a ranking and saved memo bit-identical to an
    /// uninterrupted run (see `dse::ckpt`).
    pub fn explore_warm_recoverable(
        &self,
        space: &DseSpace,
        memo: &mut super::warm::EvalMemo,
        objective: Objective,
        workers: usize,
        order: super::prune::OrderMode,
        recovery: &mut super::ckpt::RecoverySession,
    ) -> anyhow::Result<(Vec<DsePoint>, super::prune::PruneStats)> {
        Ok(super::prune::explore_pruned_warm_recoverable(
            &[(self, space)],
            Some(memo),
            order,
            objective,
            workers,
            Some(recovery),
        )?
        .pop()
        .expect("one input yields one output"))
    }

    /// [`SweepContext::explore_warm`] with a cooperative cancellation
    /// hook, polled at chunk-synchronous round **barriers** only: the
    /// in-flight round always completes, so every round that did run is
    /// bit-identical to the uncancelled sweep's. A fired hook aborts with
    /// a [`SweepCancelled`](super::SweepCancelled)-carrying error
    /// *before* any memo recording — a cancelled sweep leaves `memo`
    /// unmodified. This is the engine behind the service daemon's
    /// per-request deadlines.
    pub fn explore_warm_cancellable(
        &self,
        space: &DseSpace,
        memo: &mut super::warm::EvalMemo,
        objective: Objective,
        workers: usize,
        order: super::prune::OrderMode,
        cancel: &(dyn Fn() -> bool + Sync),
    ) -> anyhow::Result<(Vec<DsePoint>, super::prune::PruneStats)> {
        super::prune::explore_pruned_warm_cancellable(
            self,
            space,
            Some(memo),
            order,
            objective,
            workers,
            Some(cancel),
        )
    }
}

/// Worker-local evaluation state: a [`Simulator`] whose buffers persist
/// across points (reset per co-design) and an estimator timing model.
pub struct SweepWorker<'c, 'p> {
    ctx: &'c SweepContext<'p>,
    sim: Simulator<'c>,
    model: EstimatorModel,
}

impl<'c, 'p> SweepWorker<'c, 'p> {
    /// Evaluate one co-design; `None` if it cannot run (skipped point).
    ///
    /// Carries the `eval.point` faultpoint, tagged by the FNV hash of the
    /// co-design name: an armed spec always manifests as a **panic** here
    /// (evaluation has no error channel), exercising the poison-isolation
    /// path of [`parallel_for_indexed_isolated`]. The tag selects points
    /// by identity, never by schedule, so the poisoned set is identical
    /// for any worker count.
    pub fn evaluate(&mut self, codesign: &CoDesign) -> Option<DsePoint> {
        if crate::util::faultpoint::armed() {
            if let Err(e) = crate::util::faultpoint::hit_tagged(
                "eval.point",
                crate::util::faultpoint::str_tag(&codesign.name),
            ) {
                panic!("{e}");
            }
        }
        let (accels, smp) = self.ctx.resolve(codesign).ok()?;
        // `resolve` already built owned instances: hand them to the
        // simulator instead of copying them a second time.
        self.sim.reset_owned(accels, smp);
        let res = self.sim.run_mut(&mut self.model);
        Some(self.ctx.point_from(codesign, &res))
    }
}

/// One application of a [`SweepSuite`]: its shared evaluation context and
/// the space to sweep.
pub struct SuiteApp<'p> {
    /// Display name (CLI tables, bench records).
    pub name: String,
    /// The primed per-application evaluation context.
    pub ctx: SweepContext<'p>,
    /// The space swept for this application.
    pub space: DseSpace,
}

/// Ranked sweep output for one application of a suite.
pub struct SuiteAppResult {
    /// The application's display name.
    pub name: String,
    /// Evaluated points, ranked by the sweep objective.
    pub points: Vec<DsePoint>,
    /// Cut statistics. Cut counters are zero for exhaustive sweeps;
    /// `unrunnable` (candidates where some kernel has no device) is
    /// filled either way, so `evaluated + unrunnable == feasible_points`
    /// always holds for exhaustive sweeps.
    pub stats: super::prune::PruneStats,
}

/// Batched multi-program sweep: several applications share **one** worker
/// pool, and each worker keeps one lazily-built [`SweepWorker`] (simulator
/// buffers included) per application, so a whole benchmark suite — e.g.
/// matmul/cholesky/lu/stencil — sweeps in a single pass instead of four
/// sequential sweeps with four pool spin-ups.
///
/// Determinism: work items are distributed by a work-stealing cursor but
/// results are merged by `(application, enumeration index)`, so every
/// application's ranking is bit-identical to running
/// [`SweepContext::explore`] (or [`SweepContext::explore_pruned`]) on it
/// alone, for any worker count.
#[derive(Default)]
pub struct SweepSuite<'p> {
    apps: Vec<SuiteApp<'p>>,
}

impl<'p> SweepSuite<'p> {
    /// An empty suite; add applications with [`SweepSuite::push`].
    pub fn new() -> Self {
        Self { apps: Vec::new() }
    }

    /// Add an application: builds and primes its [`SweepContext`].
    pub fn push(
        &mut self,
        name: &str,
        program: &'p TaskProgram,
        board: &'p BoardConfig,
        part: &FpgaPart,
        space: DseSpace,
    ) {
        let ctx = SweepContext::for_space(program, board, part, &space);
        self.apps.push(SuiteApp {
            name: name.to_string(),
            ctx,
            space,
        });
    }

    /// [`SweepSuite::push`] with the application's HLS cache primed from
    /// the level-1 kernel sub-memo ([`SweepContext::prime_with_memo`]), so
    /// a warm suite re-runs zero cost-model calls for kernels any earlier
    /// run — any app, any problem size — already characterized.
    pub fn push_warm(
        &mut self,
        name: &str,
        program: &'p TaskProgram,
        board: &'p BoardConfig,
        part: &FpgaPart,
        space: DseSpace,
        memo: &super::warm::EvalMemo,
    ) {
        let ctx = SweepContext::for_space_warm(program, board, part, &space, memo);
        self.apps.push(SuiteApp {
            name: name.to_string(),
            ctx,
            space,
        });
    }

    /// The registered applications.
    pub fn apps(&self) -> &[SuiteApp<'p>] {
        &self.apps
    }

    /// Evaluate a flattened `(application, candidate index)` work list
    /// through one shared worker pool: one lazily-built worker (simulator
    /// + model) per thread per application, reused for every point that
    /// thread evaluates for that application. Results come back sorted by
    /// `(application, enumeration index)` — the merge order every suite
    /// sweep (cold, warm, exhaustive) shares, which is what makes them
    /// all bit-identical for any worker count. Points whose evaluation
    /// panicked come back separately as sorted `(application, candidate)`
    /// poison records; the pool survives them.
    fn evaluate_flat(
        &self,
        per_app: &[Vec<CoDesign>],
        flat: &[(usize, usize)],
        workers: usize,
    ) -> (Vec<(usize, usize, DsePoint)>, Vec<(usize, usize)>) {
        let workers = workers.clamp(1, flat.len().max(1));
        let mut slots: Vec<Vec<Option<SweepWorker<'_, 'p>>>> = (0..workers)
            .map(|_| (0..self.apps.len()).map(|_| None).collect())
            .collect();
        let (mut indexed, poisoned) = parallel_for_indexed_isolated(
            &mut slots,
            flat.len(),
            |pool, i| {
                let (ai, ci) = flat[i];
                let w = pool[ai].get_or_insert_with(|| self.apps[ai].ctx.worker());
                w.evaluate(&per_app[ai][ci]).map(|p| (ai, ci, p))
            },
            // A panic can unwind mid-simulation, so every worker in the
            // poisoned slot is rebuilt rather than trusted.
            |pool| pool.iter_mut().for_each(|w| *w = None),
        );
        indexed.sort_unstable_by_key(|&(ai, ci, _)| (ai, ci));
        let mut poisoned: Vec<(usize, usize)> = poisoned.into_iter().map(|i| flat[i]).collect();
        poisoned.sort_unstable();
        (indexed, poisoned)
    }

    /// Exhaustively sweep every application in a single pass over one
    /// shared worker pool. Per-application output is bit-identical to
    /// [`SweepContext::explore`] on that application alone.
    pub fn explore(&self, objective: Objective, workers: usize) -> Vec<SuiteAppResult> {
        // Flatten (app, candidate) work items across the whole suite.
        let per_app: Vec<Vec<CoDesign>> = self
            .apps
            .iter()
            .map(|a| a.ctx.enumerate(&a.space))
            .collect();
        let flat: Vec<(usize, usize)> = per_app
            .iter()
            .enumerate()
            .flat_map(|(ai, cands)| (0..cands.len()).map(move |ci| (ai, ci)))
            .collect();
        let (indexed, poisoned) = self.evaluate_flat(&per_app, &flat, workers);
        let mut results: Vec<SuiteAppResult> = self
            .apps
            .iter()
            .enumerate()
            .map(|(ai, a)| SuiteAppResult {
                name: a.name.clone(),
                points: Vec::new(),
                stats: super::prune::PruneStats {
                    feasible_points: per_app[ai].len() as u64,
                    ..Default::default()
                },
            })
            .collect();
        for (ai, _, p) in indexed {
            results[ai].points.push(p);
        }
        for &(ai, _) in &poisoned {
            results[ai].stats.poisoned += 1;
        }
        for r in &mut results {
            r.stats.evaluated = r.points.len() as u64;
            // Candidates the evaluation skipped (some kernel had nowhere
            // to run) — account for them so `evaluated < feasible_points`
            // can never read as pruning in an exhaustive sweep. Poisoned
            // points are quarantined in their own counter.
            r.stats.unrunnable =
                r.stats.feasible_points - r.stats.evaluated - r.stats.poisoned;
            r.points
                .sort_by(|a, b| a.score(objective).partial_cmp(&b.score(objective)).unwrap());
        }
        results
    }

    /// Bound-guided pruned sweep of the whole suite through one shared
    /// worker pool (see [`dse::prune`](super::prune)): per application,
    /// the best point and Pareto front equal [`SweepSuite::explore`]'s
    /// while strictly fewer points are simulated.
    pub fn explore_pruned(&self, objective: Objective, workers: usize) -> Vec<SuiteAppResult> {
        let inputs: Vec<(&SweepContext<'p>, &DseSpace)> =
            self.apps.iter().map(|a| (&a.ctx, &a.space)).collect();
        super::prune::explore_pruned_multi(&inputs, objective, workers)
            .into_iter()
            .zip(&self.apps)
            .map(|((points, stats), app)| SuiteAppResult {
                name: app.name.clone(),
                points,
                stats,
            })
            .collect()
    }

    /// Warm-started bound-guided pruned sweep of the whole suite — every
    /// job's memo hits, warm incumbents and level-1 ordering priors, all
    /// through **one** shared worker pool (the multi-job warm rounds of
    /// [`dse::prune`](super::prune)). Per application the output is
    /// bit-identical to [`SweepContext::explore_warm`] on that application
    /// alone against the same memo, for any worker count; a second warm
    /// run over an unchanged suite evaluates zero points. Fresh
    /// evaluations and kernel statistics are recorded back into `memo`.
    pub fn explore_pruned_warm(
        &self,
        memo: &mut super::warm::EvalMemo,
        objective: Objective,
        workers: usize,
        order: super::prune::OrderMode,
    ) -> Vec<SuiteAppResult> {
        let inputs: Vec<(&SweepContext<'p>, &DseSpace)> =
            self.apps.iter().map(|a| (&a.ctx, &a.space)).collect();
        super::prune::explore_pruned_warm_multi(&inputs, Some(memo), order, objective, workers)
            .into_iter()
            .zip(&self.apps)
            .map(|((points, stats), app)| SuiteAppResult {
                name: app.name.clone(),
                points,
                stats,
            })
            .collect()
    }

    /// Warm-started **exhaustive** sweep of the whole suite: every
    /// feasible candidate is returned, but candidates recorded in the memo
    /// are served bit-identically without simulation and only the misses
    /// run through the shared pool. Per-application output is
    /// bit-identical to [`SweepSuite::explore`] on that application alone,
    /// for any worker count. Fresh evaluations and kernel statistics are
    /// recorded back into `memo`.
    pub fn explore_warm(
        &self,
        memo: &mut super::warm::EvalMemo,
        objective: Objective,
        workers: usize,
    ) -> Vec<SuiteAppResult> {
        let per_app: Vec<Vec<CoDesign>> = self
            .apps
            .iter()
            .map(|a| a.ctx.enumerate(&a.space))
            .collect();
        let keys: Vec<Vec<String>> = per_app
            .iter()
            .map(|cands| cands.iter().map(super::warm::codesign_key).collect())
            .collect();
        let fps: Vec<u64> = self
            .apps
            .iter()
            .map(|a| super::warm::context_fingerprint(&a.ctx))
            .collect();
        // Level-2 hits per app, served without simulation.
        let mut hits: Vec<Vec<(usize, DsePoint)>> = Vec::new();
        let mut done: Vec<Vec<bool>> = Vec::new();
        for (ai, cands) in per_app.iter().enumerate() {
            memo.touch(fps[ai]);
            let mut app_hits = Vec::new();
            let mut app_done = vec![false; cands.len()];
            for (ci, key) in keys[ai].iter().enumerate() {
                if let Some(v) = memo.lookup(fps[ai], key) {
                    app_done[ci] = true;
                    app_hits.push((
                        ci,
                        DsePoint {
                            codesign: cands[ci].clone(),
                            est_ms: v.est_ms,
                            energy_j: v.energy_j,
                            edp: v.edp,
                            fabric_util: v.fabric_util,
                        },
                    ));
                }
            }
            hits.push(app_hits);
            done.push(app_done);
        }
        // Evaluate the misses through one shared pool, merged by
        // (application, enumeration index) as everywhere else.
        let mut flat: Vec<(usize, usize)> = Vec::new();
        for (ai, app_done) in done.iter().enumerate() {
            for (ci, &served) in app_done.iter().enumerate() {
                if !served {
                    flat.push((ai, ci));
                }
            }
        }
        let (indexed, poisoned) = self.evaluate_flat(&per_app, &flat, workers);
        // Record both levels, then assemble per-app results.
        let mut fresh: Vec<Vec<(usize, DsePoint)>> =
            (0..self.apps.len()).map(|_| Vec::new()).collect();
        for (ai, ci, p) in indexed {
            fresh[ai].push((ci, p));
        }
        let mut poisoned_per_app = vec![0u64; self.apps.len()];
        for &(ai, _) in &poisoned {
            poisoned_per_app[ai] += 1;
        }
        let mut results: Vec<SuiteAppResult> = Vec::new();
        for (ai, app) in self.apps.iter().enumerate() {
            memo.record_kernels(&app.ctx, &app.space);
            for (ci, p) in &fresh[ai] {
                memo.record(&app.ctx, fps[ai], &keys[ai][*ci], p);
            }
            let fresh_points: Vec<DsePoint> =
                fresh[ai].iter().map(|(_, p)| p.clone()).collect();
            memo.record_occupancy(&app.ctx, &fresh_points);

            let mut all = hits[ai].clone();
            all.extend(fresh[ai].iter().cloned());
            all.sort_unstable_by_key(|e| e.0);
            let mut points: Vec<DsePoint> = all.into_iter().map(|(_, p)| p).collect();
            let stats = super::prune::PruneStats {
                feasible_points: per_app[ai].len() as u64,
                evaluated: fresh[ai].len() as u64,
                memo_hits: hits[ai].len() as u64,
                kernel_hits: app.ctx.kernel_memo_hits() as u64,
                poisoned: poisoned_per_app[ai],
                unrunnable: per_app[ai].len() as u64
                    - fresh[ai].len() as u64
                    - hits[ai].len() as u64
                    - poisoned_per_app[ai],
                ..Default::default()
            };
            points.sort_by(|a, b| a.score(objective).partial_cmp(&b.score(objective)).unwrap());
            results.push(SuiteAppResult {
                name: app.name.clone(),
                points,
                stats,
            });
        }
        results
    }
}

/// The seed *evaluation* path, kept for benchmarking and equivalence
/// testing: rebuilds the dependence graph and elaborated program for
/// **every** point (inside `sim::estimate`) and re-runs the HLS cost model
/// per point — exactly what `SweepContext` eliminates. (Candidate
/// enumeration goes through the shared wrapper, so both paths sweep the
/// identical candidate list; the timed difference is per-point
/// evaluation, which dominates.)
pub fn explore_rebuild_baseline(
    program: &TaskProgram,
    board: &BoardConfig,
    part: &FpgaPart,
    space: &DseSpace,
    objective: Objective,
) -> anyhow::Result<Vec<DsePoint>> {
    let cm = CostModel::from_board(board);
    let pm = PowerModel::default();
    let mut points = Vec::new();
    for cd in super::enumerate(program, board, part, space) {
        // Skip configurations where some kernel has nowhere to run.
        let Ok(res) = crate::sim::estimate(program, &cd, board) else {
            continue;
        };
        let resources: Vec<Resources> = cd
            .accels
            .iter()
            .map(|a| {
                let kid = program.kernel_id(&a.kernel).unwrap();
                cm.estimate(&a.kernel, &program.kernel(kid).profile, a.unroll)
                    .resources
            })
            .collect();
        let util = part.utilization(&resources);
        let energy = pm.energy(&res, &resources, util, board.fabric_freq_mhz);
        points.push(DsePoint {
            codesign: cd,
            est_ms: res.makespan_ms(),
            energy_j: energy.total_j(),
            edp: energy.edp(),
            fabric_util: util,
        });
    }
    points.sort_by(|a, b| a.score(objective).partial_cmp(&b.score(objective)).unwrap());
    Ok(points)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::matmul::Matmul;
    use crate::dse::KernelSpace;

    fn space() -> DseSpace {
        DseSpace {
            kernels: vec![KernelSpace {
                kernel: "mxm64".into(),
                unrolls: vec![8, 16, 32],
                max_instances: 2,
                try_smp: true,
            }],
            mixed: false,
        }
    }

    #[test]
    fn mixed_enumeration_is_a_superset_with_heterogeneous_pairs() {
        let board = BoardConfig::zynq706();
        let p = Matmul::new(512, 64).build_program(&board);
        let part = FpgaPart::xc7z045();
        let sp = space();
        let mixed = sp.clone().with_mixed();
        let ctx = SweepContext::for_space(&p, &board, &part, &mixed);
        let homogeneous = ctx.enumerate(&sp);
        let cds = ctx.enumerate(&mixed);
        // Every homogeneous candidate appears in the mixed space.
        for h in &homogeneous {
            assert!(cds.contains(h), "missing homogeneous candidate {}", h.name);
        }
        assert!(cds.len() > homogeneous.len());
        // And a genuinely heterogeneous pair exists (two different unrolls
        // of the same kernel).
        assert!(cds.iter().any(|c| c.accels.len() == 2
            && c.accels[0].unroll != c.accels[1].unroll));
    }

    #[test]
    fn context_enumeration_matches_free_function() {
        let board = BoardConfig::zynq706();
        let p = Matmul::new(512, 64).build_program(&board);
        let part = FpgaPart::xc7z045();
        let sp = space();
        let ctx = SweepContext::for_space(&p, &board, &part, &sp);
        let a = ctx.enumerate(&sp);
        let b = super::super::enumerate(&p, &board, &part, &sp);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x, y);
        }
    }

    #[test]
    fn prime_fills_the_cache() {
        let board = BoardConfig::zynq706();
        let p = Matmul::new(512, 64).build_program(&board);
        let sp = space();
        let mut ctx = SweepContext::new(&p, &board, FpgaPart::xc7z045());
        assert_eq!(ctx.cached_reports(), 0);
        ctx.prime(&sp);
        assert_eq!(ctx.cached_reports(), 3);
        // Idempotent.
        ctx.prime(&sp);
        assert_eq!(ctx.cached_reports(), 3);
        // Cache hits equal fresh estimates.
        let kid = p.kernel_id("mxm64").unwrap();
        let cached = ctx.report_for(kid, "mxm64", 16);
        let fresh = CostModel::from_board(&board).estimate("mxm64", &p.kernel(kid).profile, 16);
        assert_eq!(cached, fresh);
        // Uncached unrolls fall through to the cost model.
        let off_space = ctx.report_for(kid, "mxm64", 64);
        let fresh64 = CostModel::from_board(&board).estimate("mxm64", &p.kernel(kid).profile, 64);
        assert_eq!(off_space, fresh64);
    }

    #[test]
    fn cached_estimate_matches_sim_estimate() {
        let board = BoardConfig::zynq706();
        let p = Matmul::new(512, 64).build_program(&board);
        let ctx = SweepContext::new(&p, &board, FpgaPart::xc7z045());
        let cd = CoDesign::new("2acc").with_accel("mxm64", 32).with_accel("mxm64", 32);
        let a = ctx.estimate(&cd).unwrap();
        let b = crate::sim::estimate(&p, &cd, &board).unwrap();
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.device_busy, b.device_busy);
        // Infeasible co-designs error through both paths.
        let huge = CoDesign::new("huge")
            .with_accel("mxm64", 512)
            .with_accel("mxm64", 512);
        assert!(ctx.estimate(&huge).is_err());
        assert!(crate::sim::estimate(&p, &huge, &board).is_err());
    }

    #[test]
    fn explore_matches_rebuild_baseline() {
        let board = BoardConfig::zynq706();
        let p = Matmul::new(512, 64).build_program(&board);
        let part = FpgaPart::xc7z045();
        let sp = space();
        let ctx = SweepContext::for_space(&p, &board, &part, &sp);
        let baseline =
            explore_rebuild_baseline(&p, &board, &part, &sp, Objective::Time).unwrap();
        for workers in [1, 2, 4] {
            let pts = ctx.explore(&sp, Objective::Time, workers);
            assert_eq!(pts.len(), baseline.len(), "workers={workers}");
            for (a, b) in pts.iter().zip(&baseline) {
                assert_eq!(a.codesign.name, b.codesign.name, "workers={workers}");
                assert_eq!(a.est_ms.to_bits(), b.est_ms.to_bits(), "workers={workers}");
                assert_eq!(
                    a.energy_j.to_bits(),
                    b.energy_j.to_bits(),
                    "workers={workers}"
                );
            }
        }
    }
}
