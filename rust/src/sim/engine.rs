//! The heterogeneous task-based dataflow simulation engine.
//!
//! Implements the paper's §IV semantics: tasks run "in a dataflow manner …
//! as soon as their dependences are ready and a device that can execute
//! them is available", with
//!
//! * **creation-cost tasks** on the SMP, chained in program order (the
//!   master thread creates tasks sequentially and also executes tasks —
//!   which is exactly how heterogeneous "+smp" configurations can starve
//!   the accelerators, the load-imbalance effect §VI describes);
//! * **DMA submit tasks** serialized on a shared software resource;
//! * **input DMA** folded into the accelerator occupancy when the platform
//!   scales input channels with accelerators (ZC706 behaviour, Fig. 3), or
//!   run on the shared channel otherwise;
//! * **output DMA tasks** serialized on the shared output channel; a
//!   device-executed task's successors are released only when its output
//!   transfer lands in shared memory.
//!
//! The engine is deterministic: FIFO queues plus a sequence-numbered event
//! heap. All stochastic behaviour lives in the [`TimingModel`]
//! implementation (the board emulator seeds an explicit PRNG).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};

use crate::util::fxhash::FxHashMap;

use crate::config::{BoardConfig, CoDesign};
use crate::coordinator::elaborate::{ElabProgram, Xfers};
use crate::coordinator::sched::Policy;
use crate::coordinator::task::{KernelId, TaskId, TaskProgram};
use crate::hls::{CostModel, FpgaPart, HlsReport};
use crate::sim::time::Ps;

/// Device classes of the coarse-grain architecture model.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum DeviceLabel {
    /// ARM core `n`.
    Smp(u32),
    /// FPGA accelerator instance `n`.
    Accel(u32),
    /// Shared DMA-programming (submit) software resource.
    DmaSubmit,
    /// Shared DMA data channel `n` (output transfers; input too when the
    /// platform does not scale input channels).
    DmaChan(u32),
}

impl DeviceLabel {
    /// Human-readable device name; accelerator rows show their kernel.
    pub fn display(&self, accel_kernels: &[String]) -> String {
        match self {
            DeviceLabel::Smp(n) => format!("SMP core {n}"),
            DeviceLabel::Accel(n) => {
                let k = accel_kernels
                    .get(*n as usize)
                    .map(String::as_str)
                    .unwrap_or("?");
                format!("FPGA acc {n} ({k})")
            }
            DeviceLabel::DmaSubmit => "DMA submit".to_string(),
            DeviceLabel::DmaChan(n) => format!("DMA out {n}"),
        }
    }
}

/// What a timeline segment represents.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SegKind {
    /// SMP-side task-creation cost (§IV creation-cost tasks).
    Creation,
    /// Task body on an ARM core.
    SmpCompute,
    /// Accelerator occupancy: input DMA + compute (or compute only when
    /// inputs ride the shared channel).
    AccelTask,
    /// DMA descriptor programming for inputs (shared submit resource).
    SubmitIn,
    /// DMA descriptor programming for outputs.
    SubmitOut,
    /// Input transfer on the shared channel (non-scaling platforms).
    DmaIn,
    /// Output transfer on the shared channel.
    DmaOut,
}

/// One busy interval of one device — the unit Paraver rows are built from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Segment {
    /// The device the interval occupies.
    pub device: DeviceLabel,
    /// What the interval represents (compute, DMA, submit, ...).
    pub kind: SegKind,
    /// The task instance the interval belongs to.
    pub task: TaskId,
    /// The task's kernel (denormalized for trace writers).
    pub kernel: KernelId,
    /// Interval start, picoseconds.
    pub start: Ps,
    /// Interval end, picoseconds.
    pub end: Ps,
}

/// Aggregate simulation output.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// End-to-end simulated time, picoseconds.
    pub makespan: Ps,
    /// Per-device busy intervals (empty when recording is disabled).
    pub segments: Vec<Segment>,
    /// Total busy time per device, picoseconds.
    pub device_busy: HashMap<DeviceLabel, Ps>,
    /// Tasks executed on SMP cores.
    pub tasks_on_smp: usize,
    /// Tasks executed on FPGA accelerators.
    pub tasks_on_accel: usize,
    /// Kernel names of the accelerator instances (for labeling).
    pub accel_kernels: Vec<String>,
}

impl SimResult {
    /// Makespan in fractional milliseconds.
    pub fn makespan_ms(&self) -> f64 {
        crate::sim::time::ps_to_ms(self.makespan)
    }

    /// Fraction of the makespan a device spent busy.
    pub fn busy_fraction(&self, dev: DeviceLabel) -> f64 {
        if self.makespan == 0 {
            return 0.0;
        }
        *self.device_busy.get(&dev).unwrap_or(&0) as f64 / self.makespan as f64
    }

    /// Sanity check used by tests and proptest harnesses: no device runs
    /// two segments at once, and all segments are within the makespan.
    pub fn validate(&self) -> Vec<String> {
        let mut errs = Vec::new();
        let mut by_dev: HashMap<DeviceLabel, Vec<(Ps, Ps)>> = HashMap::new();
        for s in &self.segments {
            if s.end < s.start {
                errs.push(format!("segment with end < start on {:?}", s.device));
            }
            if s.end > self.makespan {
                errs.push(format!("segment beyond makespan on {:?}", s.device));
            }
            by_dev.entry(s.device).or_default().push((s.start, s.end));
        }
        for (dev, mut iv) in by_dev {
            iv.sort_unstable();
            for w in iv.windows(2) {
                if w[1].0 < w[0].1 {
                    errs.push(format!(
                        "overlap on {dev:?}: [{},{}) and [{},{})",
                        w[0].0, w[0].1, w[1].0, w[1].1
                    ));
                }
            }
        }
        errs
    }
}

/// Dispatch context handed to the timing model.
pub struct TaskCtx<'a> {
    /// The task being dispatched.
    pub task: TaskId,
    /// The task's kernel.
    pub kernel: KernelId,
    /// The whole program (task/kernel lookups).
    pub program: &'a TaskProgram,
    /// The task's transfer footprint.
    pub xfers: Xfers,
    /// HLS report of the target accelerator (None for SMP execution).
    pub report: Option<&'a HlsReport>,
    /// Accelerator instances serving this kernel in the active co-design.
    pub accels_for_kernel: u32,
    /// Concurrently active DMA streams (inputs riding accel occupancy plus
    /// busy shared channels) — contention input for the board model.
    pub active_dma_streams: u32,
    /// Input dependences whose producer last ran on a different device
    /// class (coherence input for the board model).
    pub cross_device_inputs: u32,
    /// Current simulated time.
    pub now: Ps,
}

/// The pluggable cost model: the coarse-grain estimator and the detailed
/// board emulator implement this trait over the same engine.
pub trait TimingModel {
    /// Whether the model consumes `TaskCtx::cross_device_inputs`. The
    /// estimator ignores coherence by design (§VI), so the engine skips
    /// the producer-map scan for it (a measurable hot-path cost).
    fn needs_coherence(&self) -> bool {
        true
    }

    /// Whether every cost method is a pure function of its arguments — no
    /// internal state evolving from call to call. Replay-safe models allow
    /// the engine's checkpoint/resume delta path
    /// ([`Simulator::run_mut_with_checkpoint`] /
    /// [`Simulator::resume_mut`]): a suffix replayed from a snapshot must
    /// see exactly the costs a scratch run would. Stateful models (the
    /// PRNG-seeded board emulator) keep the `false` default, which forces
    /// scratch evaluation.
    fn replay_safe(&self) -> bool {
        false
    }

    /// Task-creation cost on the SMP (§IV creation-cost tasks).
    fn creation_ps(&mut self, board: &BoardConfig) -> Ps;
    /// Task-body latency on an ARM core.
    fn smp_compute_ps(&mut self, ctx: &TaskCtx, board: &BoardConfig) -> Ps;
    /// Accelerator occupancy. When `input_in_occupancy` (platform scales
    /// input channels) this includes the input DMA time.
    fn accel_occupancy_ps(&mut self, ctx: &TaskCtx, board: &BoardConfig, input_in_occupancy: bool)
        -> Ps;
    /// DMA-submit (descriptor programming) cost for `n_transfers` descriptors.
    fn submit_ps(&mut self, n_transfers: u32, board: &BoardConfig) -> Ps;
    /// Shared-channel transfer (output DMA always; input DMA when the
    /// platform does not scale input channels).
    fn dma_ps(&mut self, bytes: u64, ctx: &TaskCtx, board: &BoardConfig) -> Ps;
}

/// An accelerator instance resolved from a co-design.
#[derive(Clone, Debug)]
pub struct AccelInstance {
    /// Kernel this instance serves.
    pub kernel: KernelId,
    /// HLS variant report (latency + resources).
    pub report: HlsReport,
}

/// Resolve a co-design against a program: build accelerator instances via
/// the HLS cost model, check FPGA feasibility, and compute per-kernel SMP
/// eligibility.
pub fn resolve_codesign(
    program: &TaskProgram,
    codesign: &CoDesign,
    board: &BoardConfig,
    part: &FpgaPart,
) -> anyhow::Result<(Vec<AccelInstance>, Vec<bool>)> {
    let cm = CostModel::from_board(board);
    let mut accels = Vec::new();
    for spec in &codesign.accels {
        let kid = program
            .kernel_id(&spec.kernel)
            .ok_or_else(|| anyhow::anyhow!("co-design accel '{}' not in program", spec.kernel))?;
        let decl = program.kernel(kid);
        if !decl.targets.fpga {
            anyhow::bail!(
                "kernel '{}' is not annotated with target device(fpga)",
                spec.kernel
            );
        }
        let report = cm.estimate(&spec.kernel, &decl.profile, spec.unroll);
        accels.push(AccelInstance {
            kernel: kid,
            report,
        });
    }
    let resources: Vec<_> = accels.iter().map(|a| a.report.resources).collect();
    if !part.fits(&resources) {
        anyhow::bail!(
            "co-design '{}' does not fit {} (utilization {:.0}%)",
            codesign.name,
            part.name,
            part.utilization(&resources) * 100.0
        );
    }
    let mut smp_eligible = Vec::with_capacity(program.kernels.len());
    for (kid, k) in program.kernels.iter().enumerate() {
        let has_accel = accels.iter().any(|a| a.kernel as usize == kid);
        let eligible = if has_accel {
            k.targets.smp && codesign.allows_smp(&k.name)
        } else {
            k.targets.smp
        };
        if !eligible && !has_accel {
            anyhow::bail!(
                "kernel '{}' can run nowhere under co-design '{}'",
                k.name,
                codesign.name
            );
        }
        smp_eligible.push(eligible);
    }
    Ok((accels, smp_eligible))
}

// ---------------------------------------------------------------------------
// Engine internals
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum SmpNode {
    Creation(TaskId),
    Compute(TaskId),
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum XferDir {
    In,
    Out,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct SubmitJob {
    task: TaskId,
    accel: u32,
    dir: XferDir,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct DmaJob {
    task: TaskId,
    accel: u32,
    dir: XferDir,
    bytes: u64,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Ev {
    SmpDone { core: u32, node: SmpNode },
    AccelDone { accel: u32, task: TaskId },
    SubmitDone { job: SubmitJob },
    DmaDone { chan: u32, job: DmaJob },
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct Entry {
    time: Ps,
    seq: u64,
    ev: Ev,
}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.time, self.seq).cmp(&(other.time, other.seq))
    }
}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ProducerClass {
    Smp,
    Fpga,
}

/// Which simulation prefix is provably independent of one kernel's
/// accelerator / SMP option, derived from the elaborated dependence graph:
/// per-task bitmaps of "belongs to the changed kernel" and "completing
/// this task can ready a changed-kernel task". Built once per neighbor
/// chain in a sweep and shared by every pair in the chain (see
/// [`crate::dse::sweep`]); [`Simulator::run_mut_with_checkpoint`] consults
/// it to place the checkpoint.
pub struct DeltaPlan {
    kernel: KernelId,
    /// task → belongs to the changed kernel.
    is_kernel_task: Vec<bool>,
    /// task → some data successor belongs to the changed kernel.
    readies_kernel_task: Vec<bool>,
}

impl DeltaPlan {
    /// Build the trigger tables for `kernel` over one elaborated program.
    pub fn new(program: &TaskProgram, elab: &ElabProgram, kernel: KernelId) -> Self {
        assert_eq!(program.tasks.len(), elab.n_tasks);
        let is_kernel_task: Vec<bool> =
            program.tasks.iter().map(|t| t.kernel == kernel).collect();
        let readies_kernel_task = (0..elab.n_tasks)
            .map(|t| {
                elab.data_succs[t]
                    .iter()
                    .any(|&s| is_kernel_task[s as usize])
            })
            .collect();
        DeltaPlan {
            kernel,
            is_kernel_task,
            readies_kernel_task,
        }
    }

    /// The kernel whose option differs between the chained candidates.
    pub fn kernel(&self) -> KernelId {
        self.kernel
    }
}

/// A resumable snapshot of the simulator's dynamic state, captured by
/// [`Simulator::run_mut_with_checkpoint`] immediately before the first
/// event whose processing could observe the [`DeltaPlan`] kernel's
/// configuration, and restored under a neighboring co-design by
/// [`Simulator::resume_mut`]. All buffers are reused across captures, so
/// one long-lived checkpoint per sweep worker costs no steady-state
/// allocation.
#[derive(Default)]
pub struct SimCheckpoint {
    valid: bool,
    now: Ps,
    seq: u64,
    events_processed: u64,
    /// Flat copy of the event heap (order-insensitive; see
    /// `save_checkpoint`).
    heap: Vec<Entry>,
    free_cores: VecDeque<u32>,
    ready_smp: VecDeque<SmpNode>,
    next_creation: TaskId,
    preds_left: Vec<u32>,
    dispatched: Vec<bool>,
    completed: Vec<bool>,
    n_completed: usize,
    accel_free: Vec<bool>,
    accel_q: Vec<VecDeque<TaskId>>,
    accel_backlog: Vec<usize>,
    submit_busy: bool,
    submit_q: VecDeque<SubmitJob>,
    chan_busy: Vec<bool>,
    chan_q: Vec<VecDeque<DmaJob>>,
    active_dma_streams: u32,
    busy_acc: Vec<Ps>,
    tasks_on_smp: usize,
    tasks_on_accel: usize,
    /// Kernel of each flat accelerator index at capture — the key for the
    /// `(kernel, ordinal)` remap on restore.
    accel_kernels: Vec<KernelId>,
    smp_cores: u32,
}

impl SimCheckpoint {
    /// An empty (invalid) checkpoint buffer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Whether a capture succeeded and the checkpoint can be resumed.
    pub fn is_valid(&self) -> bool {
        self.valid
    }

    /// Drop the capture (e.g. when a worker moves to an unrelated chain).
    pub fn invalidate(&mut self) {
        self.valid = false;
    }

    /// Events the captured prefix had processed — the complement of the
    /// replayed suffix in reuse accounting.
    pub fn events(&self) -> u64 {
        self.events_processed
    }
}

/// The simulator.
///
/// Construct one per (program, policy) and call [`Simulator::run`] with a
/// timing model, or — on the sweep hot path — keep it alive across
/// co-designs: [`Simulator::reset`] swaps in the next co-design while
/// reusing the event heap, ready queues, `preds_left` storage and busy
/// accumulators, and [`Simulator::run_mut`] runs without consuming the
/// simulator. [`Simulator::set_record_segments`] disables per-segment
/// recording for sweeps that only need makespan + busy accounting, which
/// removes the last per-event heap allocation.
pub struct Simulator<'a> {
    program: &'a TaskProgram,
    elab: &'a ElabProgram,
    board: &'a BoardConfig,
    accels: Vec<AccelInstance>,
    smp_eligible: Vec<bool>,
    policy: Policy,

    now: Ps,
    seq: u64,
    heap: BinaryHeap<Reverse<Entry>>,

    free_cores: VecDeque<u32>,
    ready_smp: VecDeque<SmpNode>,
    next_creation: TaskId,

    preds_left: Vec<u32>,
    dispatched: Vec<bool>,
    completed: Vec<bool>,
    n_completed: usize,

    accel_free: Vec<bool>,
    /// Accelerator instances per kernel id (dense; empty = no accel).
    kernel_accels: Vec<Vec<u32>>,
    accel_q: Vec<VecDeque<TaskId>>,
    /// Tasks queued or running per kernel's accelerators (backlog estimate
    /// for the look-ahead policy).
    accel_backlog: Vec<usize>,

    submit_busy: bool,
    submit_q: VecDeque<SubmitJob>,

    chan_busy: Vec<bool>,
    chan_q: Vec<VecDeque<DmaJob>>,

    producer: FxHashMap<u64, ProducerClass>,
    /// Set from `TimingModel::needs_coherence` at run start.
    track_coherence: bool,
    active_dma_streams: u32,
    /// Events popped since the last reset — a deterministic progress
    /// counter the delta path derives evaluated-suffix fractions from.
    events_processed: u64,

    segments: Vec<Segment>,
    /// When false (sweep mode), skip building `segments` entirely; busy
    /// accounting and makespan stay exact.
    record_segments: bool,
    /// Dense busy accumulator: [smp cores | accels | submit | chans].
    busy_acc: Vec<Ps>,
    tasks_on_smp: usize,
    tasks_on_accel: usize,
}

impl<'a> Simulator<'a> {
    /// Build a simulator for one (program, board, co-design, policy)
    /// tuple. On sweep hot paths, keep it alive and [`Simulator::reset`]
    /// it per co-design instead of constructing a new one.
    pub fn new(
        program: &'a TaskProgram,
        elab: &'a ElabProgram,
        board: &'a BoardConfig,
        accels: &[AccelInstance],
        smp_eligible: &[bool],
        policy: Policy,
    ) -> Self {
        assert_eq!(program.tasks.len(), elab.n_tasks);
        assert!(board.smp_cores >= 1, "need at least one SMP core");
        let n_kernels = program.kernels.len();
        let mut sim = Simulator {
            program,
            elab,
            board,
            accels: Vec::new(),
            smp_eligible: Vec::new(),
            policy,
            now: 0,
            seq: 0,
            heap: BinaryHeap::with_capacity(64 + elab.n_tasks / 2),
            free_cores: VecDeque::with_capacity(board.smp_cores as usize),
            ready_smp: VecDeque::new(),
            next_creation: 0,
            preds_left: Vec::with_capacity(elab.n_tasks),
            dispatched: Vec::with_capacity(elab.n_tasks),
            completed: Vec::with_capacity(elab.n_tasks),
            n_completed: 0,
            accel_free: Vec::new(),
            kernel_accels: vec![Vec::new(); n_kernels],
            accel_q: vec![VecDeque::new(); n_kernels],
            accel_backlog: vec![0usize; n_kernels],
            submit_busy: false,
            submit_q: VecDeque::new(),
            chan_busy: Vec::new(),
            chan_q: Vec::new(),
            producer: FxHashMap::default(),
            track_coherence: true,
            active_dma_streams: 0,
            events_processed: 0,
            segments: Vec::with_capacity(elab.n_tasks * 4),
            record_segments: true,
            busy_acc: Vec::new(),
            tasks_on_smp: 0,
            tasks_on_accel: 0,
        };
        sim.reset(accels, smp_eligible);
        sim
    }

    /// Reconfigure for the next co-design and rewind simulated time,
    /// reusing every internal buffer (heap, queues, predecessor counters,
    /// busy accumulators). Copies the accelerator instances; sweep loops
    /// that already own them should use [`Simulator::reset_owned`] to avoid
    /// the extra clone.
    pub fn reset(&mut self, accels: &[AccelInstance], smp_eligible: &[bool]) {
        self.accels.clear();
        self.accels.extend_from_slice(accels);
        self.smp_eligible.clear();
        self.smp_eligible.extend_from_slice(smp_eligible);
        self.reset_run_state();
    }

    /// Like [`Simulator::reset`] but takes ownership of the co-design
    /// state, so per-point sweep evaluation performs no accelerator copy.
    pub fn reset_owned(&mut self, accels: Vec<AccelInstance>, smp_eligible: Vec<bool>) {
        self.accels = accels;
        self.smp_eligible = smp_eligible;
        self.reset_run_state();
    }

    fn reset_run_state(&mut self) {
        let n_tasks = self.elab.n_tasks;
        let n_kernels = self.program.kernels.len();

        self.now = 0;
        self.seq = 0;
        self.heap.clear();
        self.free_cores.clear();
        self.free_cores.extend(0..self.board.smp_cores);
        self.ready_smp.clear();
        self.next_creation = 0;
        self.preds_left.clear();
        self.preds_left.extend_from_slice(&self.elab.compute_preds);
        self.dispatched.clear();
        self.dispatched.resize(n_tasks, false);
        self.completed.clear();
        self.completed.resize(n_tasks, false);
        self.n_completed = 0;

        self.accel_free.clear();
        self.accel_free.resize(self.accels.len(), true);
        for v in &mut self.kernel_accels {
            v.clear();
        }
        self.kernel_accels.resize(n_kernels, Vec::new());
        for (i, a) in self.accels.iter().enumerate() {
            self.kernel_accels[a.kernel as usize].push(i as u32);
        }
        for q in &mut self.accel_q {
            q.clear();
        }
        self.accel_q.resize(n_kernels, VecDeque::new());
        self.accel_backlog.clear();
        self.accel_backlog.resize(n_kernels, 0);

        self.submit_busy = false;
        self.submit_q.clear();

        let n_chans = if self.board.dma_out_scales {
            self.accels.len().max(1)
        } else {
            1
        };
        for q in &mut self.chan_q {
            q.clear();
        }
        self.chan_q.resize(n_chans, VecDeque::new());
        self.chan_busy.clear();
        self.chan_busy.resize(n_chans, false);

        self.producer.clear();
        self.active_dma_streams = 0;
        self.events_processed = 0;

        self.segments.clear();
        self.busy_acc.clear();
        self.busy_acc
            .resize(self.board.smp_cores as usize + self.accels.len() + 1 + n_chans, 0);
        self.tasks_on_smp = 0;
        self.tasks_on_accel = 0;
    }

    /// Disable (or re-enable) per-segment timeline recording. Sweeps that
    /// only rank co-designs by makespan/energy turn it off; trace-producing
    /// runs (Paraver, validation) leave it on (the default).
    pub fn set_record_segments(&mut self, record: bool) {
        self.record_segments = record;
    }

    /// Hand a segment buffer from a previous [`SimResult`] back to the
    /// simulator so the next recording run reuses its capacity instead of
    /// growing a fresh vector from zero. `run_mut` moves the recorded
    /// segments out into the result, which would otherwise leave the
    /// simulator with an empty, capacity-less buffer — the one remaining
    /// per-run allocation on trace-producing (Paraver / board-emulator)
    /// repetition loops. The buffer is cleared; the recorded contents of
    /// subsequent runs are bit-identical either way (regression-tested in
    /// `sim::tests` and `engine::tests`).
    pub fn recycle_segments(&mut self, mut segments: Vec<Segment>) {
        segments.clear();
        if segments.capacity() > self.segments.capacity() {
            self.segments = segments;
        }
    }

    fn push_event(&mut self, time: Ps, ev: Ev) {
        self.seq += 1;
        self.heap.push(Reverse(Entry {
            time,
            seq: self.seq,
            ev,
        }));
    }

    fn record(&mut self, device: DeviceLabel, kind: SegKind, task: TaskId, start: Ps, end: Ps) {
        if self.record_segments {
            let kernel = self.program.tasks[task as usize].kernel;
            self.segments.push(Segment {
                device,
                kind,
                task,
                kernel,
                start,
                end,
            });
        }
        let di = self.dense_index(device);
        self.busy_acc[di] += end - start;
    }

    /// Dense index of a device in `busy_acc`.
    fn dense_index(&self, d: DeviceLabel) -> usize {
        let cores = self.board.smp_cores as usize;
        let n_acc = self.accels.len();
        match d {
            DeviceLabel::Smp(c) => c as usize,
            DeviceLabel::Accel(i) => cores + i as usize,
            DeviceLabel::DmaSubmit => cores + n_acc,
            DeviceLabel::DmaChan(n) => cores + n_acc + 1 + n as usize,
        }
    }

    fn ctx<'s>(&'s self, task: TaskId, report: Option<&'s HlsReport>) -> TaskCtx<'s> {
        let t = &self.program.tasks[task as usize];
        let accels_for_kernel = self.kernel_accels[t.kernel as usize].len() as u32;
        let cross = if self.track_coherence && !self.producer.is_empty() {
            t.deps
                .iter()
                .filter(|d| {
                    d.dir.reads()
                        && matches!(
                            (self.producer.get(&d.addr), report),
                            (Some(ProducerClass::Smp), Some(_))
                                | (Some(ProducerClass::Fpga), None)
                        )
                })
                .count() as u32
        } else {
            0
        };
        TaskCtx {
            task,
            kernel: t.kernel,
            program: self.program,
            xfers: self.elab.xfers[task as usize],
            report,
            accels_for_kernel,
            active_dma_streams: self.active_dma_streams,
            cross_device_inputs: cross,
            now: self.now,
        }
    }

    /// Run to completion. Panics on deadlock (which would indicate an
    /// engine bug — the dependence graph is acyclic by construction).
    pub fn run(mut self, timing: &mut dyn TimingModel) -> SimResult {
        self.run_mut(timing)
    }

    /// Like [`Simulator::run`] but leaves the simulator alive so a sweep
    /// can [`Simulator::reset`] it for the next co-design. Call `reset`
    /// before every subsequent `run_mut`.
    pub fn run_mut(&mut self, timing: &mut dyn TimingModel) -> SimResult {
        self.track_coherence = timing.needs_coherence();
        self.seed(timing);
        self.drain_events(timing);
        self.finish()
    }

    /// Enqueue the first creation task and fill the free cores.
    fn seed(&mut self, timing: &mut dyn TimingModel) {
        if self.elab.n_tasks > 0 {
            self.ready_smp.push_back(SmpNode::Creation(0));
            self.next_creation = 1;
        }
        self.dispatch_smp(timing);
    }

    /// Pop and process events until the heap runs dry.
    fn drain_events(&mut self, timing: &mut dyn TimingModel) {
        while let Some(Reverse(e)) = self.heap.pop() {
            debug_assert!(e.time >= self.now);
            self.now = e.time;
            self.events_processed += 1;
            self.step(e.ev, timing);
        }
    }

    #[inline]
    fn step(&mut self, ev: Ev, timing: &mut dyn TimingModel) {
        match ev {
            Ev::SmpDone { core, node } => self.on_smp_done(core, node, timing),
            Ev::AccelDone { accel, task } => self.on_accel_done(accel, task, timing),
            Ev::SubmitDone { job } => self.on_submit_done(job, timing),
            Ev::DmaDone { chan, job } => self.on_dma_done(chan, job, timing),
        }
    }

    /// Assemble the [`SimResult`] once the event heap is empty.
    fn finish(&mut self) -> SimResult {
        assert_eq!(
            self.n_completed, self.elab.n_tasks,
            "deadlock: {}/{} tasks completed",
            self.n_completed, self.elab.n_tasks
        );

        let accel_kernels = self
            .accels
            .iter()
            .map(|a| self.program.kernel(a.kernel).name.clone())
            .collect();
        SimResult {
            makespan: self.now,
            segments: std::mem::take(&mut self.segments),
            device_busy: {
                let cores = self.board.smp_cores as usize;
                let n_acc = self.accels.len();
                let mut m = HashMap::new();
                for (i, &busy) in self.busy_acc.iter().enumerate() {
                    if busy == 0 {
                        continue;
                    }
                    let dev = if i < cores {
                        DeviceLabel::Smp(i as u32)
                    } else if i < cores + n_acc {
                        DeviceLabel::Accel((i - cores) as u32)
                    } else if i == cores + n_acc {
                        DeviceLabel::DmaSubmit
                    } else {
                        DeviceLabel::DmaChan((i - cores - n_acc - 1) as u32)
                    };
                    m.insert(dev, busy);
                }
                m
            },
            tasks_on_smp: self.tasks_on_smp,
            tasks_on_accel: self.tasks_on_accel,
            accel_kernels,
        }
    }

    // --- incremental re-simulation (delta path) ------------------------------

    /// Events popped since the last reset (or injected checkpoint).
    pub fn events_processed(&self) -> u64 {
        self.events_processed
    }

    /// Like [`Simulator::run_mut`], but additionally captures a
    /// [`SimCheckpoint`] immediately **before** the first event whose
    /// processing could make a task of `plan`'s kernel ready — the longest
    /// prefix whose schedule provably never reads that kernel's
    /// configuration (its accelerator instances, variant reports or SMP
    /// eligibility). The checkpoint is left invalid when the trigger fires
    /// before any event was processed (the changed kernel sits at the DAG
    /// root, so there is nothing to reuse), when the timing model is not
    /// [`TimingModel::replay_safe`], or when coherence tracking / segment
    /// recording is on (that state is not snapshotted). The returned
    /// result is bit-identical to [`Simulator::run_mut`] in every case.
    pub fn run_mut_with_checkpoint(
        &mut self,
        timing: &mut dyn TimingModel,
        plan: &DeltaPlan,
        ckpt: &mut SimCheckpoint,
    ) -> SimResult {
        self.track_coherence = timing.needs_coherence();
        ckpt.valid = false;
        self.seed(timing);
        let can_snapshot =
            timing.replay_safe() && !self.track_coherence && !self.record_segments;
        while let Some(&Reverse(e)) = self.heap.peek() {
            if self.is_delta_trigger(plan, &e.ev) {
                if can_snapshot && self.events_processed > 0 {
                    self.save_checkpoint(ckpt);
                }
                break;
            }
            let Reverse(e) = self.heap.pop().unwrap();
            debug_assert!(e.time >= self.now);
            self.now = e.time;
            self.events_processed += 1;
            self.step(e.ev, timing);
        }
        self.drain_events(timing);
        self.finish()
    }

    /// Restart from a checkpoint under a neighboring co-design: rebuild
    /// the per-candidate layout, inject the snapshot — remapping flat
    /// accelerator indices by `(kernel, ordinal)` where instance counts
    /// shifted — and replay only the suffix. Returns `None` (leaving the
    /// simulator in need of a reset) whenever the restore is not provably
    /// safe: invalid checkpoint, non-replay-safe timing model, coherence
    /// tracking or segment recording on, a changed shared-DMA-channel
    /// count (`dma_out_scales` boards whose accelerator total moved), or a
    /// snapshot reference to an accelerator instance the new co-design no
    /// longer has. Callers fall back to scratch evaluation; on `Some`, the
    /// result is bit-identical to a scratch [`Simulator::run_mut`].
    pub fn resume_mut(
        &mut self,
        timing: &mut dyn TimingModel,
        ckpt: &SimCheckpoint,
        accels: Vec<AccelInstance>,
        smp_eligible: Vec<bool>,
    ) -> Option<SimResult> {
        self.track_coherence = timing.needs_coherence();
        if !ckpt.valid
            || !timing.replay_safe()
            || self.track_coherence
            || self.record_segments
            || ckpt.smp_cores != self.board.smp_cores
        {
            return None;
        }
        self.reset_owned(accels, smp_eligible);
        if self.chan_busy.len() != ckpt.chan_busy.len() {
            return None;
        }
        // Flat accelerator indices shift when an earlier kernel's instance
        // count changes; identify instances by (kernel, ordinal) instead.
        // Unmapped entries belong to the changed kernel, which the prefix
        // provably never touched — any reference to one aborts the resume.
        let mut map: Vec<Option<u32>> = Vec::with_capacity(ckpt.accel_kernels.len());
        let mut ord = vec![0usize; self.program.kernels.len()];
        for &k in &ckpt.accel_kernels {
            let o = ord[k as usize];
            ord[k as usize] += 1;
            map.push(self.kernel_accels[k as usize].get(o).copied());
        }
        let remap = |a: u32| map[a as usize];
        self.now = ckpt.now;
        self.seq = ckpt.seq;
        self.events_processed = ckpt.events_processed;
        self.heap.clear();
        for &e in &ckpt.heap {
            let ev = match e.ev {
                Ev::AccelDone { accel, task } => Ev::AccelDone {
                    accel: remap(accel)?,
                    task,
                },
                Ev::SubmitDone { job } => Ev::SubmitDone {
                    job: SubmitJob {
                        accel: remap(job.accel)?,
                        ..job
                    },
                },
                Ev::DmaDone { chan, job } => Ev::DmaDone {
                    chan,
                    job: DmaJob {
                        accel: remap(job.accel)?,
                        ..job
                    },
                },
                smp @ Ev::SmpDone { .. } => smp,
            };
            self.heap.push(Reverse(Entry { ev, ..e }));
        }
        self.free_cores.clone_from(&ckpt.free_cores);
        self.ready_smp.clone_from(&ckpt.ready_smp);
        self.next_creation = ckpt.next_creation;
        self.preds_left.clone_from(&ckpt.preds_left);
        self.dispatched.clone_from(&ckpt.dispatched);
        self.completed.clone_from(&ckpt.completed);
        self.n_completed = ckpt.n_completed;
        // The new co-design may have more or fewer instances of the
        // changed kernel than the snapshot; those are all still free.
        for f in &mut self.accel_free {
            *f = true;
        }
        for (i, &free) in ckpt.accel_free.iter().enumerate() {
            match map[i] {
                Some(ni) => self.accel_free[ni as usize] = free,
                None => debug_assert!(free, "changed-kernel instance busy in prefix"),
            }
        }
        for (q, cq) in self.accel_q.iter_mut().zip(&ckpt.accel_q) {
            q.clone_from(cq);
        }
        self.accel_backlog.clone_from(&ckpt.accel_backlog);
        self.submit_busy = ckpt.submit_busy;
        self.submit_q.clear();
        for &job in &ckpt.submit_q {
            let accel = remap(job.accel)?;
            self.submit_q.push_back(SubmitJob { accel, ..job });
        }
        self.chan_busy.clone_from(&ckpt.chan_busy);
        for (q, cq) in self.chan_q.iter_mut().zip(&ckpt.chan_q) {
            q.clear();
            for &job in cq {
                let accel = remap(job.accel)?;
                q.push_back(DmaJob { accel, ..job });
            }
        }
        self.active_dma_streams = ckpt.active_dma_streams;
        // Busy accumulators: [smp cores | accels | submit | chans], with
        // the accel block permuted through the same (kernel, ordinal) map.
        let cores = self.board.smp_cores as usize;
        let old_acc = ckpt.accel_kernels.len();
        let new_acc = self.accels.len();
        self.busy_acc[..cores].copy_from_slice(&ckpt.busy_acc[..cores]);
        for (i, m) in map.iter().enumerate() {
            let busy = ckpt.busy_acc[cores + i];
            match *m {
                Some(ni) => self.busy_acc[cores + ni as usize] = busy,
                None => debug_assert_eq!(busy, 0),
            }
        }
        self.busy_acc[cores + new_acc] = ckpt.busy_acc[cores + old_acc];
        for c in 0..self.chan_busy.len() {
            self.busy_acc[cores + new_acc + 1 + c] = ckpt.busy_acc[cores + old_acc + 1 + c];
        }
        self.tasks_on_smp = ckpt.tasks_on_smp;
        self.tasks_on_accel = ckpt.tasks_on_accel;
        self.drain_events(timing);
        Some(self.finish())
    }

    /// Would processing `ev` call `make_ready` on a task of the plan's
    /// kernel? Exact — checked against the live `preds_left` counters, so
    /// an event that merely *decrements* a changed-kernel task's counter
    /// keeps the prefix going. `make_ready` is the first (and only) point
    /// the engine reads a kernel's configuration for one of its tasks, so
    /// snapshotting before this event is what makes the prefix reusable.
    fn is_delta_trigger(&self, plan: &DeltaPlan, ev: &Ev) -> bool {
        match *ev {
            Ev::SmpDone {
                node: SmpNode::Creation(t),
                ..
            } => plan.is_kernel_task[t as usize] && self.preds_left[t as usize] == 1,
            Ev::SmpDone {
                node: SmpNode::Compute(t),
                ..
            } => self.completion_readies(plan, t),
            Ev::AccelDone { task, .. } => {
                // Completes immediately only when there is no output DMA.
                self.elab.xfers[task as usize].bytes_out == 0
                    && self.completion_readies(plan, task)
            }
            Ev::DmaDone { job, .. } => {
                job.dir == XferDir::Out && self.completion_readies(plan, job.task)
            }
            Ev::SubmitDone { .. } => false,
        }
    }

    /// Whether completing `task` right now would ready a changed-kernel
    /// successor.
    fn completion_readies(&self, plan: &DeltaPlan, task: TaskId) -> bool {
        plan.readies_kernel_task[task as usize]
            && self.elab.data_succs[task as usize]
                .iter()
                .any(|&s| plan.is_kernel_task[s as usize] && self.preds_left[s as usize] == 1)
    }

    /// Snapshot every piece of dynamic state into `ckpt`, reusing its
    /// buffers. The heap is stored as a flat entry list: the total
    /// `(time, seq)` order makes pop order independent of the internal
    /// arrangement, so re-heapifying on restore is lossless.
    fn save_checkpoint(&self, ckpt: &mut SimCheckpoint) {
        ckpt.now = self.now;
        ckpt.seq = self.seq;
        ckpt.events_processed = self.events_processed;
        ckpt.heap.clear();
        ckpt.heap.extend(self.heap.iter().map(|r| r.0));
        ckpt.free_cores.clone_from(&self.free_cores);
        ckpt.ready_smp.clone_from(&self.ready_smp);
        ckpt.next_creation = self.next_creation;
        ckpt.preds_left.clone_from(&self.preds_left);
        ckpt.dispatched.clone_from(&self.dispatched);
        ckpt.completed.clone_from(&self.completed);
        ckpt.n_completed = self.n_completed;
        ckpt.accel_free.clone_from(&self.accel_free);
        ckpt.accel_q.clone_from(&self.accel_q);
        ckpt.accel_backlog.clone_from(&self.accel_backlog);
        ckpt.submit_busy = self.submit_busy;
        ckpt.submit_q.clone_from(&self.submit_q);
        ckpt.chan_busy.clone_from(&self.chan_busy);
        ckpt.chan_q.clone_from(&self.chan_q);
        ckpt.active_dma_streams = self.active_dma_streams;
        ckpt.busy_acc.clone_from(&self.busy_acc);
        ckpt.tasks_on_smp = self.tasks_on_smp;
        ckpt.tasks_on_accel = self.tasks_on_accel;
        ckpt.accel_kernels.clear();
        ckpt.accel_kernels.extend(self.accels.iter().map(|a| a.kernel));
        ckpt.smp_cores = self.board.smp_cores;
        ckpt.valid = true;
    }

    // --- SMP ---------------------------------------------------------------

    fn dispatch_smp(&mut self, timing: &mut dyn TimingModel) {
        while !self.free_cores.is_empty() {
            let Some(node) = self.pop_smp_node(timing) else {
                break;
            };
            let core = self.free_cores.pop_front().unwrap();
            let (dur, kind, task) = match node {
                SmpNode::Creation(t) => (timing.creation_ps(self.board), SegKind::Creation, t),
                SmpNode::Compute(t) => {
                    self.dispatched[t as usize] = true;
                    self.tasks_on_smp += 1;
                    let ctx = self.ctx(t, None);
                    (
                        timing.smp_compute_ps(&ctx, self.board),
                        SegKind::SmpCompute,
                        t,
                    )
                }
            };
            let end = self.now + dur;
            self.record(DeviceLabel::Smp(core), kind, task, self.now, end);
            self.push_event(end, Ev::SmpDone { core, node });
        }
    }

    /// Pop the next SMP-runnable node, honoring the scheduling policy and
    /// skipping entries already taken by an accelerator.
    fn pop_smp_node(&mut self, timing: &mut dyn TimingModel) -> Option<SmpNode> {
        let mut deferred: Vec<SmpNode> = Vec::new();
        let mut found = None;
        while let Some(node) = self.ready_smp.pop_front() {
            match node {
                SmpNode::Creation(_) => {
                    found = Some(node);
                    break;
                }
                SmpNode::Compute(t) => {
                    if self.dispatched[t as usize] {
                        continue; // an accelerator already took it
                    }
                    let kernel = self.program.tasks[t as usize].kernel;
                    let accels = self.kernel_accels[kernel as usize].len() as u32;
                    if accels == 0 {
                        found = Some(node);
                        break;
                    }
                    let backlog = self.accel_backlog[kernel as usize];
                    let accel_ps = self.accel_task_estimate(kernel);
                    let ctx = self.ctx(t, None);
                    let smp_ps = timing.smp_compute_ps(&ctx, self.board);
                    if self
                        .policy
                        .smp_should_take(backlog, accel_ps, accels, smp_ps)
                    {
                        found = Some(node);
                        break;
                    } else {
                        // Leave it to the accelerators; it stays in their
                        // queue. Do not retain in the SMP queue (it will be
                        // handled by the accel path).
                        continue;
                    }
                }
            }
        }
        // Preserve FIFO order of deferred entries (none currently deferred,
        // kept for future policies that requeue).
        for d in deferred.drain(..).rev() {
            self.ready_smp.push_front(d);
        }
        found
    }

    /// Nominal per-task accelerator latency for backlog estimates.
    fn accel_task_estimate(&self, kernel: KernelId) -> Ps {
        self.kernel_accels[kernel as usize]
            .first()
            .map(|&i| {
                let r = &self.accels[i as usize].report;
                r.compute_ps() + r.in_ps()
            })
            .unwrap_or(0)
    }

    fn on_smp_done(&mut self, core: u32, node: SmpNode, timing: &mut dyn TimingModel) {
        self.free_cores.push_back(core);
        match node {
            SmpNode::Creation(t) => {
                // Chain: next creation becomes ready.
                if (self.next_creation as usize) < self.elab.n_tasks {
                    let c = self.next_creation;
                    self.next_creation += 1;
                    self.ready_smp.push_back(SmpNode::Creation(c));
                }
                self.satisfy_pred(t, timing);
            }
            SmpNode::Compute(t) => {
                self.complete_task(t, ProducerClass::Smp, timing);
            }
        }
        self.dispatch_smp(timing);
    }

    // --- readiness ---------------------------------------------------------

    fn satisfy_pred(&mut self, task: TaskId, timing: &mut dyn TimingModel) {
        let p = &mut self.preds_left[task as usize];
        debug_assert!(*p > 0);
        *p -= 1;
        if *p == 0 {
            self.make_ready(task, timing);
        }
    }

    fn make_ready(&mut self, task: TaskId, timing: &mut dyn TimingModel) {
        let kernel = self.program.tasks[task as usize].kernel;
        let has_accel = !self.kernel_accels[kernel as usize].is_empty();
        if has_accel {
            self.accel_q[kernel as usize].push_back(task);
            self.accel_backlog[kernel as usize] += 1;
        }
        if self.smp_eligible[kernel as usize] {
            self.ready_smp.push_back(SmpNode::Compute(task));
        }
        if has_accel {
            self.dispatch_accels(kernel, timing);
        }
        self.dispatch_smp(timing);
    }

    fn complete_task(&mut self, task: TaskId, class: ProducerClass, timing: &mut dyn TimingModel) {
        debug_assert!(!self.completed[task as usize]);
        self.completed[task as usize] = true;
        self.n_completed += 1;
        if self.track_coherence {
            for d in &self.program.tasks[task as usize].deps {
                if d.dir.writes() {
                    self.producer.insert(d.addr, class);
                }
            }
        }
        // `elab` is an `&'a` shared borrow independent of `&mut self`, so
        // the successor list can be walked in place — no per-event clone.
        let elab = self.elab;
        for &s in &elab.data_succs[task as usize] {
            self.satisfy_pred(s, timing);
        }
    }

    // --- accelerators --------------------------------------------------------

    fn dispatch_accels(&mut self, kernel: KernelId, timing: &mut dyn TimingModel) {
        loop {
            let Some(accel) = self.kernel_accels[kernel as usize]
                .iter()
                .find(|&&i| self.accel_free[i as usize])
                .copied()
            else {
                return;
            };
            let Some(task) = self.pop_accel_task(kernel) else {
                return;
            };
            self.dispatched[task as usize] = true;
            self.tasks_on_accel += 1;
            self.accel_free[accel as usize] = false;
            // §IV: the DMA programming (submit) runs first on the shared
            // software resource; the accelerator waits for its data.
            self.enqueue_submit(
                SubmitJob {
                    task,
                    accel,
                    dir: XferDir::In,
                },
                timing,
            );
        }
    }

    fn pop_accel_task(&mut self, kernel: KernelId) -> Option<TaskId> {
        let q = &mut self.accel_q[kernel as usize];
        while let Some(t) = q.pop_front() {
            if !self.dispatched[t as usize] {
                return Some(t);
            }
            // Taken by the SMP meanwhile: drop from backlog.
            self.accel_backlog[kernel as usize] -= 1;
        }
        None
    }

    fn enqueue_submit(&mut self, job: SubmitJob, timing: &mut dyn TimingModel) {
        self.submit_q.push_back(job);
        self.pump_submit(timing);
    }

    fn pump_submit(&mut self, timing: &mut dyn TimingModel) {
        if self.submit_busy {
            return;
        }
        let Some(job) = self.submit_q.pop_front() else {
            return;
        };
        self.submit_busy = true;
        let x = self.elab.xfers[job.task as usize];
        let n = match job.dir {
            XferDir::In => x.n_in,
            XferDir::Out => x.n_out,
        };
        let dur = timing.submit_ps(n, self.board);
        let kind = match job.dir {
            XferDir::In => SegKind::SubmitIn,
            XferDir::Out => SegKind::SubmitOut,
        };
        let end = self.now + dur;
        self.record(DeviceLabel::DmaSubmit, kind, job.task, self.now, end);
        self.push_event(end, Ev::SubmitDone { job });
    }

    fn on_submit_done(&mut self, job: SubmitJob, timing: &mut dyn TimingModel) {
        self.submit_busy = false;
        match job.dir {
            XferDir::In => {
                if self.board.dma_in_scales {
                    // Input DMA rides the accelerator's own channel: start
                    // the accelerator occupancy (input + compute).
                    self.start_accel_occupancy(job.accel, job.task, true, timing);
                } else {
                    // Input goes over the shared channel first.
                    let bytes = self.elab.xfers[job.task as usize].bytes_in;
                    self.enqueue_dma(
                        DmaJob {
                            task: job.task,
                            accel: job.accel,
                            dir: XferDir::In,
                            bytes,
                        },
                        timing,
                    );
                }
            }
            XferDir::Out => {
                let bytes = self.elab.xfers[job.task as usize].bytes_out;
                self.enqueue_dma(
                    DmaJob {
                        task: job.task,
                        accel: job.accel,
                        dir: XferDir::Out,
                        bytes,
                    },
                    timing,
                );
            }
        }
        self.pump_submit(timing);
    }

    fn start_accel_occupancy(
        &mut self,
        accel: u32,
        task: TaskId,
        input_in_occupancy: bool,
        timing: &mut dyn TimingModel,
    ) {
        self.active_dma_streams += u32::from(input_in_occupancy);
        let report = &self.accels[accel as usize].report;
        let ctx = self.ctx(task, Some(report));
        let dur = timing.accel_occupancy_ps(&ctx, self.board, input_in_occupancy);
        self.active_dma_streams -= u32::from(input_in_occupancy);
        // Conservative: count the in-flight input stream for the duration.
        if input_in_occupancy {
            self.active_dma_streams += 1;
        }
        let end = self.now + dur;
        self.record(
            DeviceLabel::Accel(accel),
            SegKind::AccelTask,
            task,
            self.now,
            end,
        );
        self.push_event(end, Ev::AccelDone { accel, task });
    }

    fn on_accel_done(&mut self, accel: u32, task: TaskId, timing: &mut dyn TimingModel) {
        if self.board.dma_in_scales {
            self.active_dma_streams = self.active_dma_streams.saturating_sub(1);
        }
        let kernel = self.accels[accel as usize].kernel;
        self.accel_free[accel as usize] = true;
        self.accel_backlog[kernel as usize] -= 1;
        // Output path: submit + shared-channel transfer, then completion.
        if self.elab.xfers[task as usize].bytes_out > 0 {
            self.enqueue_submit(
                SubmitJob {
                    task,
                    accel,
                    dir: XferDir::Out,
                },
                timing,
            );
        } else {
            self.complete_task(task, ProducerClass::Fpga, timing);
        }
        self.dispatch_accels(kernel, timing);
    }

    // --- shared DMA channels -------------------------------------------------

    fn chan_for(&self, job: &DmaJob) -> u32 {
        if self.chan_busy.len() == 1 {
            0
        } else {
            job.accel % self.chan_busy.len() as u32
        }
    }

    fn enqueue_dma(&mut self, job: DmaJob, timing: &mut dyn TimingModel) {
        let chan = self.chan_for(&job);
        self.chan_q[chan as usize].push_back(job);
        self.pump_chan(chan, timing);
    }

    fn pump_chan(&mut self, chan: u32, timing: &mut dyn TimingModel) {
        if self.chan_busy[chan as usize] {
            return;
        }
        let Some(job) = self.chan_q[chan as usize].pop_front() else {
            return;
        };
        self.chan_busy[chan as usize] = true;
        self.active_dma_streams += 1;
        let ctx = self.ctx(job.task, None);
        let dur = timing.dma_ps(job.bytes, &ctx, self.board);
        let kind = match job.dir {
            XferDir::In => SegKind::DmaIn,
            XferDir::Out => SegKind::DmaOut,
        };
        let end = self.now + dur;
        self.record(DeviceLabel::DmaChan(chan), kind, job.task, self.now, end);
        self.push_event(end, Ev::DmaDone { chan, job });
    }

    fn on_dma_done(&mut self, chan: u32, job: DmaJob, timing: &mut dyn TimingModel) {
        self.chan_busy[chan as usize] = false;
        self.active_dma_streams = self.active_dma_streams.saturating_sub(1);
        match job.dir {
            XferDir::In => {
                // Data landed in the accelerator: start compute only.
                self.start_accel_occupancy(job.accel, job.task, false, timing);
            }
            XferDir::Out => {
                self.complete_task(job.task, ProducerClass::Fpga, timing);
            }
        }
        self.pump_chan(chan, timing);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::deps::DepGraph;
    use crate::coordinator::task::{Dep, KernelDecl, KernelProfile, Targets};
    use crate::sim::estimator::EstimatorModel;

    fn small_profile() -> KernelProfile {
        KernelProfile {
            flops: 1000,
            inner_trip: 1000,
            in_bytes: 1024,
            out_bytes: 512,
            dtype_bytes: 4,
            divsqrt: false,
        }
    }

    /// A profile whose accelerator occupancy (~656 us input DMA) dwarfs the
    /// creation cost, so device throughput — not task issue — dominates.
    fn heavy_profile() -> KernelProfile {
        KernelProfile {
            flops: 1_000_000,
            inner_trip: 1_000_000,
            in_bytes: 256 * 1024,
            out_bytes: 16 * 1024,
            dtype_bytes: 4,
            divsqrt: false,
        }
    }

    fn chain_program(n: usize, targets: Targets) -> TaskProgram {
        let mut p = TaskProgram::new("chain");
        let k = p.add_kernel(KernelDecl {
            name: "k".into(),
            targets,
            profile: small_profile(),
        });
        for _ in 0..n {
            p.add_task(k, 10_000, vec![Dep::inout(0x1000, 512)]);
        }
        p
    }

    fn run_config(
        program: &TaskProgram,
        codesign: &CoDesign,
        board: &BoardConfig,
    ) -> SimResult {
        let graph = DepGraph::build(program);
        let elab = ElabProgram::build(program, &graph);
        let (accels, smp) =
            resolve_codesign(program, codesign, board, &FpgaPart::xc7z045()).unwrap();
        let sim = Simulator::new(program, &elab, board, &accels, &smp, Policy::Greedy);
        let mut model = EstimatorModel::new(board);
        let res = sim.run(&mut model);
        assert!(res.validate().is_empty(), "{:?}", res.validate());
        res
    }

    #[test]
    fn smp_only_chain_serializes() {
        let board = BoardConfig::zynq706();
        let p = chain_program(10, Targets::SMP);
        let cd = CoDesign::new("smp");
        let res = run_config(&p, &cd, &board);
        assert_eq!(res.tasks_on_smp, 10);
        assert_eq!(res.tasks_on_accel, 0);
        // Makespan >= serial compute (chain) — creation overlaps.
        let smp_clock = board.smp_clock();
        let serial = smp_clock.cycles_to_ps(10 * 10_000);
        assert!(res.makespan >= serial);
    }

    #[test]
    fn fpga_only_chain_uses_accel() {
        let board = BoardConfig::zynq706();
        let p = chain_program(10, Targets::FPGA);
        let cd = CoDesign::new("fpga").with_accel("k", 4);
        let res = run_config(&p, &cd, &board);
        assert_eq!(res.tasks_on_accel, 10);
        assert_eq!(res.tasks_on_smp, 0);
        // Submit + DMA segments must exist.
        assert!(res.segments.iter().any(|s| s.kind == SegKind::SubmitIn));
        assert!(res.segments.iter().any(|s| s.kind == SegKind::DmaOut));
    }

    #[test]
    fn independent_tasks_scale_with_accels() {
        let board = BoardConfig::zynq706();
        let mut p = TaskProgram::new("par");
        let k = p.add_kernel(KernelDecl {
            name: "k".into(),
            targets: Targets::FPGA,
            profile: heavy_profile(),
        });
        for i in 0..64u64 {
            p.add_task(
                k,
                10_000,
                vec![
                    Dep::input(0x100_0000 + i * 262_144, 262_144),
                    Dep::inout(0x1000 + i * 16_384, 16_384),
                ],
            );
        }
        let r1 = run_config(&p, &CoDesign::new("1acc").with_accel("k", 4), &board);
        let r2 = run_config(
            &p,
            &CoDesign::new("2acc").with_accel("k", 4).with_accel("k", 4),
            &board,
        );
        assert!(
            (r2.makespan as f64) < 0.75 * r1.makespan as f64,
            "2 accels should be well under 1 accel: {} vs {}",
            r2.makespan,
            r1.makespan
        );
    }

    #[test]
    fn hetero_uses_both_devices() {
        let board = BoardConfig::zynq706();
        let mut p = TaskProgram::new("par");
        let k = p.add_kernel(KernelDecl {
            name: "k".into(),
            targets: Targets::BOTH,
            profile: heavy_profile(),
        });
        for i in 0..64u64 {
            p.add_task(
                k,
                500_000, // ~0.75 ms on the A9 — comparable to the accel task
                vec![
                    Dep::input(0x100_0000 + i * 262_144, 262_144),
                    Dep::inout(0x1000 + i * 16_384, 16_384),
                ],
            );
        }
        let cd = CoDesign::new("1acc+smp").with_accel("k", 4).with_smp("k");
        let res = run_config(&p, &cd, &board);
        assert!(res.tasks_on_smp > 0, "SMP should steal some tasks");
        assert!(res.tasks_on_accel > 0);
        assert_eq!(res.tasks_on_smp + res.tasks_on_accel, 64);
    }

    #[test]
    fn output_dma_serializes_on_shared_channel() {
        let board = BoardConfig::zynq706();
        let mut p = TaskProgram::new("par");
        let k = p.add_kernel(KernelDecl {
            name: "k".into(),
            targets: Targets::FPGA,
            profile: small_profile(),
        });
        for i in 0..8u64 {
            p.add_task(k, 10_000, vec![Dep::inout(0x1000 + i * 4096, 512)]);
        }
        let cd = CoDesign::new("2acc").with_accel("k", 4).with_accel("k", 4);
        let res = run_config(&p, &cd, &board);
        // All DmaOut segments must be on channel 0 and non-overlapping
        // (validated by res.validate() already); check the channel count.
        assert!(res
            .segments
            .iter()
            .filter(|s| s.kind == SegKind::DmaOut)
            .all(|s| s.device == DeviceLabel::DmaChan(0)));
    }

    #[test]
    fn non_scaling_input_platform_routes_input_through_channel() {
        let mut board = BoardConfig::zynq706();
        board.dma_in_scales = false;
        let p = chain_program(4, Targets::FPGA);
        let cd = CoDesign::new("1acc").with_accel("k", 4);
        let graph = DepGraph::build(&p);
        let elab = ElabProgram::build(&p, &graph);
        let (accels, smp) =
            resolve_codesign(&p, &cd, &board, &FpgaPart::xc7z045()).unwrap();
        let sim = Simulator::new(&p, &elab, &board, &accels, &smp, Policy::Greedy);
        let mut model = EstimatorModel::new(&board);
        let res = sim.run(&mut model);
        assert!(res.segments.iter().any(|s| s.kind == SegKind::DmaIn));
    }

    #[test]
    fn infeasible_codesign_rejected() {
        let board = BoardConfig::zynq706();
        let p = chain_program(1, Targets::FPGA);
        let cd = CoDesign::new("huge")
            .with_accel("k", 128)
            .with_accel("k", 128);
        let err = resolve_codesign(&p, &cd, &board, &FpgaPart::xc7z045());
        assert!(err.is_err());
    }

    #[test]
    fn kernel_with_no_home_rejected() {
        let board = BoardConfig::zynq706();
        let p = chain_program(1, Targets::FPGA);
        let cd = CoDesign::new("empty"); // no accel, kernel not smp-capable
        assert!(resolve_codesign(&p, &cd, &board, &FpgaPart::xc7z045()).is_err());
    }

    #[test]
    fn deterministic_across_runs() {
        let board = BoardConfig::zynq706();
        let p = chain_program(20, Targets::FPGA);
        let cd = CoDesign::new("1acc").with_accel("k", 4);
        let a = run_config(&p, &cd, &board);
        let b = run_config(&p, &cd, &board);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.segments.len(), b.segments.len());
    }

    #[test]
    fn reset_reuse_matches_fresh_run() {
        let board = BoardConfig::zynq706();
        let p = chain_program(20, Targets::FPGA);
        let cd = CoDesign::new("1acc").with_accel("k", 4);
        let graph = DepGraph::build(&p);
        let elab = ElabProgram::build(&p, &graph);
        let (accels, smp) =
            resolve_codesign(&p, &cd, &board, &FpgaPart::xc7z045()).unwrap();
        let fresh = run_config(&p, &cd, &board);

        let mut sim = Simulator::new(&p, &elab, &board, &accels, &smp, Policy::Greedy);
        let mut model = EstimatorModel::new(&board);
        let a = sim.run_mut(&mut model);
        // Sweep mode: reuse the buffers, skip the timeline.
        sim.reset(&accels, &smp);
        sim.set_record_segments(false);
        let b = sim.run_mut(&mut model);
        // And back: re-enabled recording restores the full timeline.
        sim.reset(&accels, &smp);
        sim.set_record_segments(true);
        let c = sim.run_mut(&mut model);

        assert_eq!(a.makespan, fresh.makespan);
        assert_eq!(b.makespan, fresh.makespan);
        assert_eq!(c.makespan, fresh.makespan);
        assert_eq!(a.segments.len(), fresh.segments.len());
        assert!(b.segments.is_empty(), "sweep mode must not record segments");
        assert_eq!(c.segments.len(), fresh.segments.len());
        assert_eq!(a.device_busy, b.device_busy);
        assert_eq!(a.device_busy, fresh.device_busy);
        assert_eq!(b.tasks_on_accel, fresh.tasks_on_accel);
    }

    #[test]
    fn recycled_segment_pool_reproduces_traces() {
        // Recording runs that hand their segment vector back via
        // `recycle_segments` must produce bit-identical timelines while
        // reusing the buffer's capacity.
        let board = BoardConfig::zynq706();
        let p = chain_program(20, Targets::FPGA);
        let cd = CoDesign::new("1acc").with_accel("k", 4);
        let graph = DepGraph::build(&p);
        let elab = ElabProgram::build(&p, &graph);
        let (accels, smp) =
            resolve_codesign(&p, &cd, &board, &FpgaPart::xc7z045()).unwrap();
        let fresh = run_config(&p, &cd, &board);

        let mut sim = Simulator::new(&p, &elab, &board, &accels, &smp, Policy::Greedy);
        let mut model = EstimatorModel::new(&board);
        let first = sim.run_mut(&mut model);
        assert_eq!(first.segments, fresh.segments);
        let recycled_cap = first.segments.capacity();
        sim.recycle_segments(first.segments);
        sim.reset(&accels, &smp);
        let second = sim.run_mut(&mut model);
        assert_eq!(second.segments, fresh.segments, "recycled run diverged");
        assert_eq!(second.makespan, fresh.makespan);
        assert!(
            second.segments.capacity() <= recycled_cap.max(fresh.segments.capacity()),
            "recycling must not grow the pool beyond one run's footprint"
        );
    }

    /// Independent SMP producers (`ka`) each feeding one FPGA consumer
    /// (`kb`) — the changed kernel sits strictly downstream, so a delta
    /// checkpoint has a non-trivial prefix to reuse.
    fn two_kernel_program(n: usize) -> TaskProgram {
        let mut p = TaskProgram::new("twok");
        let ka = p.add_kernel(KernelDecl {
            name: "ka".into(),
            targets: Targets::SMP,
            profile: small_profile(),
        });
        let kb = p.add_kernel(KernelDecl {
            name: "kb".into(),
            targets: Targets::FPGA,
            profile: heavy_profile(),
        });
        for i in 0..n as u64 {
            p.add_task(ka, 50_000, vec![Dep::inout(0x1000 + i * 0x100, 256)]);
            p.add_task(
                kb,
                10_000,
                vec![
                    Dep::input(0x1000 + i * 0x100, 256),
                    Dep::inout(0x100_0000 + i * 0x4000, 16_384),
                ],
            );
        }
        p
    }

    #[test]
    fn checkpoint_resume_matches_scratch_run() {
        let board = BoardConfig::zynq706();
        let p = two_kernel_program(12);
        let graph = DepGraph::build(&p);
        let elab = ElabProgram::build(&p, &graph);
        let part = FpgaPart::xc7z045();
        let kb = p.kernel_id("kb").unwrap();
        let head = CoDesign::new("1xkb4").with_accel("kb", 4);
        // One unroll neighbor, one instance-count neighbor.
        let neighbors = [
            CoDesign::new("1xkb8").with_accel("kb", 8),
            CoDesign::new("2xkb4").with_accel("kb", 4).with_accel("kb", 4),
        ];
        let (accels, smp) = resolve_codesign(&p, &head, &board, &part).unwrap();
        let mut sim = Simulator::new(&p, &elab, &board, &accels, &smp, Policy::Greedy);
        sim.set_record_segments(false);
        let mut model = EstimatorModel::new(&board);
        let plan = DeltaPlan::new(&p, &elab, kb);
        let mut ckpt = SimCheckpoint::new();
        let head_res = sim.run_mut_with_checkpoint(&mut model, &plan, &mut ckpt);
        assert!(ckpt.is_valid(), "kb is downstream of ka: prefix must exist");
        assert!(ckpt.events() > 0);
        // The checkpointing run itself is bit-identical to a scratch run.
        sim.reset(&accels, &smp);
        let head_scratch = sim.run_mut(&mut model);
        assert_eq!(head_res.makespan, head_scratch.makespan);
        assert_eq!(head_res.device_busy, head_scratch.device_busy);
        for cd in &neighbors {
            let (na, ns) = resolve_codesign(&p, cd, &board, &part).unwrap();
            let resumed = sim
                .resume_mut(&mut model, &ckpt, na.clone(), ns.clone())
                .expect("provably safe delta must resume");
            let suffix = sim.events_processed() - ckpt.events();
            assert!(suffix > 0, "{}: suffix must replay events", cd.name);
            sim.reset(&na, &ns);
            let scratch = sim.run_mut(&mut model);
            assert_eq!(resumed.makespan, scratch.makespan, "{}", cd.name);
            assert_eq!(resumed.device_busy, scratch.device_busy, "{}", cd.name);
            assert_eq!(resumed.tasks_on_smp, scratch.tasks_on_smp, "{}", cd.name);
            assert_eq!(resumed.tasks_on_accel, scratch.tasks_on_accel, "{}", cd.name);
            assert_eq!(
                sim.events_processed(),
                ckpt.events() + suffix,
                "scratch replays the same event count"
            );
        }
    }

    #[test]
    fn root_kernel_delta_has_no_checkpoint() {
        // The changed kernel's first task is the first thing the schedule
        // readies: nothing precedes it, so there is no prefix to save and
        // the delta must fall back to scratch.
        let board = BoardConfig::zynq706();
        let p = chain_program(10, Targets::FPGA);
        let graph = DepGraph::build(&p);
        let elab = ElabProgram::build(&p, &graph);
        let part = FpgaPart::xc7z045();
        let cd = CoDesign::new("1acc").with_accel("k", 4);
        let (accels, smp) = resolve_codesign(&p, &cd, &board, &part).unwrap();
        let mut sim = Simulator::new(&p, &elab, &board, &accels, &smp, Policy::Greedy);
        sim.set_record_segments(false);
        let mut model = EstimatorModel::new(&board);
        let plan = DeltaPlan::new(&p, &elab, p.kernel_id("k").unwrap());
        let mut ckpt = SimCheckpoint::new();
        let res = sim.run_mut_with_checkpoint(&mut model, &plan, &mut ckpt);
        assert!(!ckpt.is_valid(), "root-kernel change must not checkpoint");
        // The run itself still completes and matches scratch.
        sim.reset(&accels, &smp);
        let scratch = sim.run_mut(&mut model);
        assert_eq!(res.makespan, scratch.makespan);
        // And an invalid checkpoint refuses to resume.
        let (na, ns) = resolve_codesign(
            &p,
            &CoDesign::new("1acc8").with_accel("k", 8),
            &board,
            &part,
        )
        .unwrap();
        assert!(sim.resume_mut(&mut model, &ckpt, na, ns).is_none());
    }

    #[test]
    fn segment_recording_disables_checkpoint_capture() {
        // Timeline segments are not snapshotted, so a recording run must
        // never hand out a checkpoint (the delta path would silently drop
        // prefix segments otherwise).
        let board = BoardConfig::zynq706();
        let p = two_kernel_program(4);
        let graph = DepGraph::build(&p);
        let elab = ElabProgram::build(&p, &graph);
        let part = FpgaPart::xc7z045();
        let cd = CoDesign::new("1xkb4").with_accel("kb", 4);
        let (accels, smp) = resolve_codesign(&p, &cd, &board, &part).unwrap();
        let mut sim = Simulator::new(&p, &elab, &board, &accels, &smp, Policy::Greedy);
        let mut model = EstimatorModel::new(&board);
        let plan = DeltaPlan::new(&p, &elab, p.kernel_id("kb").unwrap());
        let mut ckpt = SimCheckpoint::new();
        let res = sim.run_mut_with_checkpoint(&mut model, &plan, &mut ckpt);
        assert!(!ckpt.is_valid());
        assert!(!res.segments.is_empty());
    }

    #[test]
    fn creation_cost_bounds_makespan_below() {
        // Even with infinitely fast devices the creation chain on the SMP
        // serializes task issue.
        let board = BoardConfig::zynq706();
        let p = chain_program(50, Targets::SMP);
        let cd = CoDesign::new("smp");
        let res = run_config(&p, &cd, &board);
        let creation_chain = crate::sim::time::us_to_ps(board.task_creation_us) * 50;
        assert!(res.makespan >= creation_chain);
    }
}
