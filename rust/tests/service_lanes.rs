//! Sharded-lane and batch-evaluation determinism (seeded forall harness,
//! same style as `sweep_determinism.rs`): for any lane count, worker
//! count and client interleaving, the daemon's responses are
//! byte-identical to the single-lane daemon handling the same per-client
//! request sequences one at a time — and a `batch` envelope answers
//! exactly what the standalone request lines would have.
//!
//! Each concurrent client owns a distinct application: apps are
//! kernel-disjoint, so every context that shares memo state stays inside
//! one client's (hence one lane's) program order, which is precisely the
//! interleaving class the lane-sharding contract promises determinism
//! for.

use std::sync::{Arc, Barrier};

use zynq_estimator::config::BoardConfig;
use zynq_estimator::service::{ServeConfig, Service};
use zynq_estimator::util::json::{parse, Value};
use zynq_estimator::util::Rng;

fn forall(iters: u64, base_seed: u64, f: impl Fn(u64, &mut Rng)) {
    for i in 0..iters {
        let seed = base_seed.wrapping_add(i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(seed);
        f(seed, &mut rng);
    }
}

/// The suite apps with their FPGA-capable kernels (bs 64 everywhere).
const APPS: [(&str, &[&str]); 4] = [
    ("matmul", &["mxm64"]),
    ("cholesky", &["dgemm", "dsyrk", "dtrsm"]),
    ("lu", &["lugemm", "trsm_row", "trsm_col"]),
    ("stencil", &["jacobi64"]),
];

fn service(lanes: usize, batch_window_ms: u64, workers: usize) -> Service {
    Service::new(
        BoardConfig::zynq706(),
        ServeConfig {
            lanes,
            batch_window_ms,
            workers,
            ..ServeConfig::default()
        },
    )
    .unwrap()
}

fn random_request(rng: &mut Rng, app: &str, kernels: &[&str], id: u64) -> String {
    let n = [128u64, 192, 256][rng.gen_range(0, 3) as usize];
    let kernel = kernels[rng.gen_range(0, kernels.len() as u64) as usize];
    let unroll = [8u64, 16, 32][rng.gen_range(0, 3) as usize];
    let req = if rng.next_f64() < 0.3 { "energy" } else { "estimate" };
    format!(
        r#"{{"id":{id},"req":"{req}","app":"{app}","n":{n},"accel":["{kernel}:U{unroll}"]}}"#
    )
}

/// Per-client request sequences: each of the first `n_clients` apps gets
/// 2–5 requests with a healthy repeat rate (repeats are what exercise
/// the memo-hit rendering path).
fn random_schedule(rng: &mut Rng, n_clients: usize) -> Vec<Vec<String>> {
    let mut schedule = Vec::new();
    for (c, (app, kernels)) in APPS.iter().take(n_clients).enumerate() {
        let mut reqs: Vec<String> = Vec::new();
        let n_reqs = 2 + rng.gen_range(0, 4);
        for r in 0..n_reqs {
            if !reqs.is_empty() && rng.next_f64() < 0.35 {
                let prev = reqs[rng.gen_range(0, reqs.len() as u64) as usize].clone();
                reqs.push(prev);
            } else {
                reqs.push(random_request(rng, app, kernels, (c * 100) as u64 + r));
            }
        }
        schedule.push(reqs);
    }
    schedule
}

fn run_sequentially(svc: &Service, schedule: &[Vec<String>]) -> Vec<Vec<String>> {
    schedule
        .iter()
        .map(|reqs| {
            reqs.iter()
                .map(|r| svc.handle_line(r).0.expect("request must answer"))
                .collect()
        })
        .collect()
}

fn run_concurrently(svc: &Arc<Service>, schedule: &[Vec<String>]) -> Vec<Vec<String>> {
    let barrier = Arc::new(Barrier::new(schedule.len()));
    let handles: Vec<_> = schedule
        .iter()
        .cloned()
        .map(|reqs| {
            let svc = Arc::clone(svc);
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                barrier.wait();
                reqs.iter()
                    .map(|r| svc.handle_line(r).0.expect("request must answer"))
                    .collect::<Vec<String>>()
            })
        })
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

#[test]
fn prop_sharded_lanes_answer_byte_identically_to_single_lane() {
    forall(6, 0x1A4E5, |seed, rng| {
        let lanes = [1usize, 2, 4, 8][rng.gen_range(0, 4) as usize];
        let workers = 1 + rng.gen_range(0, 4) as usize;
        let n_clients = 2 + rng.gen_range(0, 3) as usize;
        let schedule = random_schedule(rng, n_clients);
        let single = service(1, 0, workers);
        let expect = run_sequentially(&single, &schedule);
        let multi = Arc::new(service(lanes, 0, workers));
        let got = run_concurrently(&multi, &schedule);
        assert_eq!(
            got, expect,
            "seed {seed} lanes={lanes} workers={workers}: sharded responses diverged"
        );
        assert_eq!(
            multi.evaluated(),
            single.evaluated(),
            "seed {seed} lanes={lanes}: aggregate evaluations diverged"
        );
        assert_eq!(
            multi.errors(),
            single.errors(),
            "seed {seed}: error counts diverged (infeasible points must fail identically)"
        );
    });
}

#[test]
fn prop_batch_envelope_answers_equal_sequential_lines() {
    forall(8, 0xBA7C4, |seed, rng| {
        let lanes = [1usize, 2, 4][rng.gen_range(0, 3) as usize];
        let workers = 1 + rng.gen_range(0, 4) as usize;
        let n_items = 1 + rng.gen_range(0, 6) as usize;
        let mut items: Vec<String> = Vec::new();
        for i in 0..n_items {
            if !items.is_empty() && rng.next_f64() < 0.3 {
                // Duplicate an earlier item verbatim: inside one batch the
                // second occurrence must render as a level-2 hit, exactly
                // like the sequential repeat does.
                items.push(items[rng.gen_range(0, items.len() as u64) as usize].clone());
            } else {
                let (app, kernels) = APPS[rng.gen_range(0, 4) as usize];
                items.push(random_request(rng, app, kernels, i as u64));
            }
        }
        let seq = service(1, 0, workers);
        let expect: Vec<String> = items
            .iter()
            .map(|r| seq.handle_line(r).0.expect("request must answer"))
            .collect();
        let svc = service(lanes, 0, workers);
        let envelope = format!(r#"{{"id":99,"req":"batch","items":[{}]}}"#, items.join(","));
        let (resp, _) = svc.handle_line(&envelope);
        let v = parse(&resp.unwrap()).unwrap();
        assert_eq!(v.get("ok").and_then(|x| x.as_bool()), Some(true), "seed {seed}");
        let Some(Value::Arr(got)) = v.get("items") else {
            panic!("seed {seed}: batch response must carry items");
        };
        assert_eq!(got.len(), n_items, "seed {seed}");
        for (i, (g, e)) in got.iter().zip(&expect).enumerate() {
            assert_eq!(
                g.to_json(),
                parse(e).unwrap().to_json(),
                "seed {seed} item {i}: batch answer diverged from the standalone line"
            );
        }
        assert_eq!(
            svc.evaluated(),
            seq.evaluated(),
            "seed {seed} lanes={lanes}: the batch must evaluate exactly the distinct cold points"
        );
        assert_eq!(
            svc.errors(),
            seq.errors(),
            "seed {seed}: failed batch items must mirror the standalone failures"
        );
    });
}

#[test]
fn prop_windowed_batching_preserves_bytes_and_total_evaluations() {
    forall(4, 0x3172D0, |seed, rng| {
        let lanes = [1usize, 2, 4][rng.gen_range(0, 3) as usize];
        let workers = 1 + rng.gen_range(0, 3) as usize;
        let window_ms = 1 + rng.gen_range(0, 5);
        let n_clients = 2 + rng.gen_range(0, 3) as usize;
        let schedule = random_schedule(rng, n_clients);
        let plain = service(1, 0, workers);
        let expect = run_sequentially(&plain, &schedule);
        let windowed = Arc::new(service(lanes, window_ms, workers));
        let got = run_concurrently(&windowed, &schedule);
        assert_eq!(
            got, expect,
            "seed {seed} lanes={lanes} window={window_ms}ms: windowed responses diverged"
        );
        assert_eq!(
            windowed.evaluated(),
            plain.evaluated(),
            "seed {seed}: the window must not change the number of evaluations"
        );
        assert!(
            windowed.batched() >= windowed.evaluated(),
            "seed {seed}: every windowed point query counts as batched"
        );
        assert_eq!(windowed.errors(), plain.errors(), "seed {seed}");
    });
}
