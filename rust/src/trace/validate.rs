//! Trace linter — §IV well-formedness checks for basic task traces.
//!
//! The paper's toolchain consumes traces produced by an instrumented
//! binary; corrupted or hand-edited traces must fail loudly *before* a
//! simulation silently produces garbage co-design decisions. The linter
//! checks everything the simulator assumes.

use std::collections::HashMap;

use crate::coordinator::task::TaskProgram;

/// Severity of a lint finding.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Severity {
    /// The simulator would mis-run or panic.
    Error,
    /// Suspicious but simulable (e.g. zero-cost tasks).
    Warning,
}

#[derive(Clone, Debug)]
/// One lint finding.
pub struct Finding {
    /// How bad it is.
    pub severity: Severity,
    /// What is wrong.
    pub message: String,
}

/// Run all checks; errors first.
pub fn lint(program: &TaskProgram) -> Vec<Finding> {
    let mut out = Vec::new();
    let mut err = |m: String| {
        out.push(Finding {
            severity: Severity::Error,
            message: m,
        })
    };

    if program.app_name.is_empty() {
        err("trace has no application name".into());
    }
    if program.kernels.is_empty() {
        err("trace declares no kernels".into());
    }

    // Structural errors (shared with TaskProgram::validate).
    for msg in program.validate() {
        err(msg);
    }

    let mut warnings = Vec::new();
    // Creation timestamps must be non-decreasing (sequential emission).
    let mut last_creation = 0u64;
    for t in &program.tasks {
        if t.creation_ns < last_creation {
            warnings.push(format!(
                "task {} created at {} ns, before its predecessor ({} ns) — \
                 trace not in sequential emission order",
                t.id, t.creation_ns, last_creation
            ));
        }
        last_creation = last_creation.max(t.creation_ns);
        if t.smp_cycles == 0 {
            warnings.push(format!("task {} has zero SMP cycles", t.id));
        }
    }

    // Dependences on addresses only ever read (never produced) are
    // program inputs — fine — but a kernel whose every instance writes an
    // address nobody reads suggests a mis-recorded direction.
    let mut read_addrs: HashMap<u64, u32> = HashMap::new();
    let mut written_addrs: HashMap<u64, u32> = HashMap::new();
    for t in &program.tasks {
        for d in &t.deps {
            if d.dir.reads() {
                *read_addrs.entry(d.addr).or_insert(0) += 1;
            }
            if d.dir.writes() {
                *written_addrs.entry(d.addr).or_insert(0) += 1;
            }
        }
    }
    let dead_writes = written_addrs
        .keys()
        .filter(|a| !read_addrs.contains_key(a))
        .count();
    if dead_writes > 0 && dead_writes == written_addrs.len() {
        warnings.push(format!(
            "none of the {} written addresses is ever read — directions \
             likely inverted in the instrumentation",
            written_addrs.len()
        ));
    }

    // Inconsistent transfer sizes per address (the paper records len per
    // dependence; differing lens on one address break transfer accounting).
    let mut len_of: HashMap<u64, u64> = HashMap::new();
    for t in &program.tasks {
        for d in &t.deps {
            match len_of.insert(d.addr, d.len) {
                Some(prev) if prev != d.len => {
                    warnings.push(format!(
                        "address {:#x} used with lengths {} and {}",
                        d.addr, prev, d.len
                    ));
                }
                _ => {}
            }
        }
    }

    for w in warnings {
        out.push(Finding {
            severity: Severity::Warning,
            message: w,
        });
    }
    out.sort_by_key(|f| match f.severity {
        Severity::Error => 0,
        Severity::Warning => 1,
    });
    out
}

/// True if the trace has no errors (warnings allowed).
pub fn is_simulable(program: &TaskProgram) -> bool {
    !lint(program)
        .iter()
        .any(|f| f.severity == Severity::Error)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::matmul::Matmul;
    use crate::config::BoardConfig;
    use crate::coordinator::task::{Dep, KernelDecl, KernelProfile, Targets};

    fn profile() -> KernelProfile {
        KernelProfile {
            flops: 1,
            inner_trip: 1,
            in_bytes: 4,
            out_bytes: 4,
            dtype_bytes: 4,
            divsqrt: false,
        }
    }

    #[test]
    fn clean_app_traces_lint_clean() {
        let b = BoardConfig::zynq706();
        for bs in [64, 128] {
            let p = Matmul::new(512, bs).build_program(&b);
            let findings = lint(&p);
            assert!(
                findings.is_empty(),
                "bs={bs}: {:?}",
                findings
            );
            assert!(is_simulable(&p));
        }
    }

    #[test]
    fn empty_trace_errors() {
        let p = TaskProgram::new("");
        let findings = lint(&p);
        assert!(findings.iter().any(|f| f.severity == Severity::Error));
        assert!(!is_simulable(&p));
    }

    #[test]
    fn zero_cycles_warn() {
        let mut p = TaskProgram::new("t");
        let k = p.add_kernel(KernelDecl {
            name: "k".into(),
            targets: Targets::SMP,
            profile: profile(),
        });
        p.add_task(k, 0, vec![Dep::inout(0x1, 4)]);
        let findings = lint(&p);
        assert!(findings
            .iter()
            .any(|f| f.severity == Severity::Warning && f.message.contains("zero SMP cycles")));
        assert!(is_simulable(&p)); // warning only
    }

    #[test]
    fn out_of_order_creation_warns() {
        let mut p = TaskProgram::new("t");
        let k = p.add_kernel(KernelDecl {
            name: "k".into(),
            targets: Targets::SMP,
            profile: profile(),
        });
        p.add_task(k, 1, vec![Dep::inout(0x1, 4)]);
        p.add_task(k, 1, vec![Dep::inout(0x1, 4)]);
        p.tasks[0].creation_ns = 100;
        p.tasks[1].creation_ns = 50;
        let findings = lint(&p);
        assert!(findings
            .iter()
            .any(|f| f.message.contains("sequential emission order")));
    }

    #[test]
    fn inconsistent_lengths_warn() {
        let mut p = TaskProgram::new("t");
        let k = p.add_kernel(KernelDecl {
            name: "k".into(),
            targets: Targets::SMP,
            profile: profile(),
        });
        p.add_task(k, 1, vec![Dep::inout(0x100, 64)]);
        p.add_task(k, 1, vec![Dep::inout(0x100, 128)]);
        let findings = lint(&p);
        assert!(findings.iter().any(|f| f.message.contains("lengths")));
    }

    #[test]
    fn all_dead_writes_warn() {
        let mut p = TaskProgram::new("t");
        let k = p.add_kernel(KernelDecl {
            name: "k".into(),
            targets: Targets::SMP,
            profile: profile(),
        });
        // Writers that nobody reads (inverted directions).
        p.add_task(k, 1, vec![Dep::output(0x100, 64)]);
        p.add_task(k, 1, vec![Dep::output(0x200, 64)]);
        let findings = lint(&p);
        assert!(findings
            .iter()
            .any(|f| f.message.contains("directions likely inverted")));
    }
}
