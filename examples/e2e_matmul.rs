//! End-to-end driver — proves all three layers compose on a real workload.
//!
//! 1. **Decide** (L3 estimator): sweep the paper's six matmul co-designs
//!    through the coarse-grain estimator and pick the winner — the
//!    minutes-instead-of-hours decision of §VI.
//! 2. **Execute** (L3 coordinator + L1/L2 artifacts): run the chosen
//!    blocked matmul *for real*: the Rust dataflow coordinator schedules
//!    every mxmBlock task over a worker pool in dependence order, and each
//!    task executes the AOT-compiled JAX/Pallas kernel through the PJRT
//!    runtime (Python is not involved). The result is validated against a
//!    pure-Rust reference.
//! 3. **Report**: wall-clock, task throughput, GFLOP/s, numeric error,
//!    plus the simulated-Zynq timings that drove the decision. Recorded in
//!    EXPERIMENTS.md §E2E.
//!
//! Run: `make artifacts && cargo run --release --example e2e_matmul [-- --n 512]`

use std::sync::Mutex;
use std::time::Instant;

use zynq_estimator::apps::matmul;
use zynq_estimator::cli::Args;
use zynq_estimator::config::BoardConfig;
use zynq_estimator::coordinator::deps::DepGraph;
use zynq_estimator::experiments;
use zynq_estimator::runtime::{executor, reference, Runtime};

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    let n = args.u64_or("n", 512)? as usize;
    let workers = args.u64_or("workers", 4)? as usize;
    let board = BoardConfig::zynq706();

    // ---- Phase 1: co-design decision via the estimator -------------------
    println!("== Phase 1: coarse-grain estimation over the Fig. 5 co-design set");
    let t0 = Instant::now();
    let table = experiments::fig5(n as u64, &board, 3)?;
    let decision_s = t0.elapsed().as_secs_f64();
    println!("{}", table.render("estimator vs board emulator"));
    let best = &table.rows[table.best_estimator()];
    println!(
        "decision: '{}' in {:.2} s (the traditional flow would synthesize every bitstream first)\n",
        best.name, decision_s
    );

    // The winning co-design tells us the granularity to run.
    let bs = if best.name.contains("128") { 128usize } else { 64usize };
    let kernel = format!("mxm{bs}");
    let nb = n / bs;

    // ---- Phase 2: real execution through the PJRT runtime ----------------
    // Degrade cleanly when the backend is stubbed out (no `pjrt` feature)
    // or the AOT artifacts are absent (no `make artifacts`): the decision
    // phase above is the estimator's answer either way, and CI smoke-runs
    // this example without a Python toolchain.
    let runtime_ready = match Runtime::new(std::path::Path::new("artifacts")) {
        // The artifact for the co-design the decision phase picked must
        // itself be present — a partial artifact set degrades too.
        Ok(rt) => rt.available().iter().any(|k| k == &kernel),
        Err(_) => false,
    };
    if !runtime_ready {
        println!("== Phase 2 skipped: PJRT backend or the '{kernel}' AOT artifact unavailable");
        println!("   (build with `--features pjrt` and run `make artifacts` to execute for real)");
        return Ok(());
    }
    println!(
        "== Phase 2: executing matmul {n}x{n} (bs={bs}, {nb}^3 = {} tasks) on {workers} workers",
        nb * nb * nb
    );
    let app = matmul::Matmul::new(n as u64, bs as u64);
    let program = app.build_program(&board);
    let graph = DepGraph::build(&program);

    // Tile storage. A/B are read-only; each C tile has its own lock —
    // dependence chains already serialize same-tile tasks, the lock only
    // protects the memcpy.
    let mut rng = zynq_estimator::util::Rng::new(0xE2E);
    let mut tile = |seed_off: u64| -> Vec<f32> {
        let _ = seed_off;
        (0..bs * bs).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect()
    };
    let a_tiles: Vec<Vec<f32>> = (0..nb * nb).map(|i| tile(i as u64)).collect();
    let b_tiles: Vec<Vec<f32>> = (0..nb * nb).map(|i| tile(1000 + i as u64)).collect();
    let c_tiles: Vec<Mutex<Vec<f32>>> =
        (0..nb * nb).map(|_| Mutex::new(vec![0f32; bs * bs])).collect();

    let t1 = Instant::now();
    let stats = executor::execute(
        &program,
        &graph,
        workers,
        // PJRT clients are not Sync: one runtime per worker.
        |_w| Runtime::new(std::path::Path::new("artifacts")),
        &|rt: &mut Runtime, task| {
            // task id encodes (k, i, j) in the emission order.
            let t = task as usize;
            let (k, rem) = (t / (nb * nb), t % (nb * nb));
            let (i, j) = (rem / nb, rem % nb);
            let a = &a_tiles[i * nb + k];
            let b = &b_tiles[k * nb + j];
            let c_in = c_tiles[i * nb + j].lock().unwrap().clone();
            let out = rt.run_mxm(&kernel, bs, a, b, &c_in)?;
            *c_tiles[i * nb + j].lock().unwrap() = out;
            Ok(())
        },
    )
    .map_err(|e| anyhow::anyhow!("{e:#} (are artifacts built? run `make artifacts`)"))?;
    let exec_s = t1.elapsed().as_secs_f64();
    let n_tasks = stats.tasks;
    println!(
        "  per-worker task counts: {:?} (library executor: runtime::executor)",
        stats.per_worker
    );

    // ---- Phase 3: validate + report --------------------------------------
    println!("== Phase 3: validation");
    // Assemble C and compare against the pure-Rust blocked reference.
    let mut a_full = vec![0f32; n * n];
    let mut b_full = vec![0f32; n * n];
    let mut c_full = vec![0f32; n * n];
    for bi in 0..nb {
        for bj in 0..nb {
            reference::paste_tile(n, bs, &mut a_full, bi, bj, &a_tiles[bi * nb + bj]);
            reference::paste_tile(n, bs, &mut b_full, bi, bj, &b_tiles[bi * nb + bj]);
            let t = c_tiles[bi * nb + bj].lock().unwrap();
            reference::paste_tile(n, bs, &mut c_full, bi, bj, &t);
        }
    }
    let mut expect = vec![0f32; n * n];
    reference::blocked_matmul(n, bs, &a_full, &b_full, &mut expect);
    let diff = reference::max_abs_diff(&c_full, &expect);
    let max = expect.iter().fold(0f32, |m, x| m.max(x.abs()));
    let rel = diff / max;
    println!("  max relative error vs reference: {rel:.2e}");
    anyhow::ensure!(rel < 1e-3, "numeric validation FAILED");

    let flops = 2.0 * (n as f64).powi(3);
    println!("\n== E2E report");
    println!("  co-design decision:        '{}' in {decision_s:.2} s", best.name);
    println!("  tasks executed via PJRT:   {n_tasks} ({:.0} tasks/s)", n_tasks as f64 / exec_s);
    println!("  wall-clock execution:      {exec_s:.3} s ({:.2} GFLOP/s on this host)",
        flops / exec_s / 1e9);
    println!("  simulated Zynq makespan:   est {:.1} ms / board {:.1} ms",
        best.estimator_ms, best.board_ms);
    println!("  numeric validation:        PASS (rel err {rel:.2e})");
    Ok(())
}
