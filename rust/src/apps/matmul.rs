//! Tiled matrix multiplication — the paper's Fig. 1 application.
//!
//! ```c
//! #pragma omp target device(fpga,smp)
//! #pragma omp task in([BS*BS]A,[BS*BS]B) inout([BS*BS]C)
//! void mxmBlock(REAL *A, REAL *B, REAL *C);
//!
//! void matmul(...) {
//!   for (k = 0; k < NB; k++)
//!     for (i = 0; i < NB; i++)
//!       for (j = 0; j < NB; j++)
//!         mxmBlock(AA[i*NB+k], BB[k*NB+j], CC[i*NB+j]);
//! }
//! ```
//!
//! The kernel is single-precision (`REAL = float`, §V). Granularities
//! evaluated by the paper: 64×64 and 128×128 blocks over the same matrix.

use crate::config::{BoardConfig, CoDesign};
use crate::coordinator::task::{
    Dep, KernelDecl, KernelProfile, TaskProgram, Targets,
};

use super::{smp_cycles_model, ExperimentSet};

/// Canonical HLS unroll for the 64-block accelerator (two fit on Z-7045).
pub const UNROLL_64: u32 = 32;
/// Canonical HLS unroll for the 128-block accelerator (only one fits —
/// §VI feasibility statement; checked in `hls::cost_model` tests).
pub const UNROLL_128: u32 = 128;

/// Base heap addresses (disjoint per matrix, as malloc would give).
const A_BASE: u64 = 0x1000_0000;
const B_BASE: u64 = 0x2000_0000;
const C_BASE: u64 = 0x3000_0000;

#[derive(Clone, Copy, Debug)]
/// Tiled matrix multiply (paper Fig. 1).
pub struct Matmul {
    /// Matrix dimension (elements). The paper's runs use 512.
    pub n: u64,
    /// Block (tile) dimension: 64 or 128 in the paper.
    pub bs: u64,
}

impl Matmul {
    /// An `n`×`n` multiply with `bs`×`bs` blocks (`n` divisible by `bs`).
    pub fn new(n: u64, bs: u64) -> Self {
        assert!(n % bs == 0, "matrix size must be a multiple of block size");
        Self { n, bs }
    }

    /// Number of tile blocks per side.
    pub fn nb(&self) -> u64 {
        self.n / self.bs
    }

    /// The kernel name for this granularity (`mxm64` / `mxm128`).
    pub fn kernel_name(&self) -> String {
        format!("mxm{}", self.bs)
    }

    /// Workload profile of one block multiply.
    pub fn profile(&self) -> KernelProfile {
        let bs = self.bs;
        KernelProfile {
            flops: 2 * bs * bs * bs,
            inner_trip: bs * bs * bs,
            in_bytes: 3 * bs * bs * 4, // A, B in + C inout (f32)
            out_bytes: bs * bs * 4,    // C out
            dtype_bytes: 4,
            divsqrt: false,
        }
    }

    fn tile_bytes(&self) -> u64 {
        self.bs * self.bs * 4
    }

    fn block_addr(&self, base: u64, row: u64, col: u64) -> u64 {
        base + (row * self.nb() + col) * self.tile_bytes()
    }

    /// Build the task program — the moral equivalent of running the
    /// instrumented sequential binary (basic trace of §IV).
    pub fn build_program(&self, board: &BoardConfig) -> TaskProgram {
        let mut p = TaskProgram::new(&format!("matmul{}-bs{}", self.n, self.bs));
        let profile = self.profile();
        let smp_cycles = smp_cycles_model(&profile, board);
        let k_id = p.add_kernel(KernelDecl {
            name: self.kernel_name(),
            targets: Targets::BOTH,
            profile,
        });
        let nb = self.nb();
        let tb = self.tile_bytes();
        for k in 0..nb {
            for i in 0..nb {
                for j in 0..nb {
                    p.add_task(
                        k_id,
                        smp_cycles,
                        vec![
                            Dep::input(self.block_addr(A_BASE, i, k), tb),
                            Dep::input(self.block_addr(B_BASE, k, j), tb),
                            Dep::inout(self.block_addr(C_BASE, i, j), tb),
                        ],
                    );
                }
            }
        }
        p
    }
}

/// The six co-designs of Fig. 5. All operate on the same 512×512 matrix;
/// the task granularity (64 vs 128) is an app-level choice, so the sweep
/// harness pairs each co-design with the right [`Matmul`] instance via
/// [`fig5_cases`].
pub fn fig5_codesigns() -> Vec<CoDesign> {
    vec![
        CoDesign::new("1acc 64").with_accel("mxm64", UNROLL_64),
        CoDesign::new("2acc 64")
            .with_accel("mxm64", UNROLL_64)
            .with_accel("mxm64", UNROLL_64),
        CoDesign::new("1acc 128").with_accel("mxm128", UNROLL_128),
        CoDesign::new("1acc 64 + smp")
            .with_accel("mxm64", UNROLL_64)
            .with_smp("mxm64"),
        CoDesign::new("2acc 64 + smp")
            .with_accel("mxm64", UNROLL_64)
            .with_accel("mxm64", UNROLL_64)
            .with_smp("mxm64"),
        CoDesign::new("1acc 128 + smp")
            .with_accel("mxm128", UNROLL_128)
            .with_smp("mxm128"),
    ]
}

/// (co-design, app instance) pairs for the Fig. 5 sweep on an `n`-sized
/// matrix (the paper: 512).
pub fn fig5_cases(n: u64) -> Vec<(CoDesign, Matmul)> {
    fig5_codesigns()
        .into_iter()
        .map(|cd| {
            let bs = if cd.accels[0].kernel == "mxm128" { 128 } else { 64 };
            (cd, Matmul::new(n, bs))
        })
        .collect()
}

/// The Fig. 5 experiment set.
pub fn fig5_experiment() -> ExperimentSet {
    ExperimentSet {
        app: "matmul".into(),
        codesigns: fig5_codesigns(),
        baseline: "1acc 128 + smp".into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::deps::DepGraph;

    #[test]
    fn task_count_is_nb_cubed() {
        let b = BoardConfig::zynq706();
        let app = Matmul::new(512, 64);
        assert_eq!(app.nb(), 8);
        let p = app.build_program(&b);
        assert_eq!(p.tasks.len(), 512);
        assert!(p.validate().is_empty());
    }

    #[test]
    fn dependence_structure_matches_blocked_matmul() {
        let b = BoardConfig::zynq706();
        let p = Matmul::new(256, 64).build_program(&b); // NB = 4
        let g = DepGraph::build(&p);
        assert!(g.respects_program_order());
        // Depth = NB: the accumulation chain on each C block.
        assert_eq!(g.depth(), 4);
        // All tasks of the first k-slice are independent.
        assert_eq!(g.max_level_width(), 16);
    }

    #[test]
    fn both_granularities_same_total_flops() {
        let b = BoardConfig::zynq706();
        let p64 = Matmul::new(512, 64).build_program(&b);
        let p128 = Matmul::new(512, 128).build_program(&b);
        let f64_total: u64 =
            p64.tasks.len() as u64 * p64.kernels[0].profile.flops;
        let f128_total: u64 =
            p128.tasks.len() as u64 * p128.kernels[0].profile.flops;
        assert_eq!(f64_total, f128_total);
        assert_eq!(f64_total, 2 * 512 * 512 * 512);
    }

    #[test]
    fn coarser_blocks_move_fewer_bytes() {
        // The key reason 128-blocks win: halved DMA traffic.
        let b = BoardConfig::zynq706();
        let bytes = |bs: u64| {
            let app = Matmul::new(512, bs);
            let p = app.build_program(&b);
            p.tasks.len() as u64 * app.profile().in_bytes
        };
        assert_eq!(bytes(64), 2 * bytes(128));
    }

    #[test]
    fn fig5_set_is_complete() {
        let cds = fig5_codesigns();
        assert_eq!(cds.len(), 6);
        let smp_variants = cds.iter().filter(|c| !c.smp_kernels.is_empty()).count();
        assert_eq!(smp_variants, 3);
        // No 2acc 128 (paper: infeasible).
        assert!(!cds
            .iter()
            .any(|c| c.accel_count_for("mxm128") > 1));
    }

    #[test]
    fn fig5_cases_pick_matching_granularity() {
        for (cd, app) in fig5_cases(512) {
            let k = &cd.accels[0].kernel;
            assert_eq!(*k, app.kernel_name());
        }
    }

    #[test]
    #[should_panic]
    fn non_divisible_size_panics() {
        Matmul::new(500, 64);
    }
}
