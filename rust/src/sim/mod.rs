//! Simulation substrate: time/clock domains, the discrete-event engine,
//! the DMA transfer model and the coarse-grain estimator timing model.
//!
//! The high-level entry points are [`estimate`] and [`emulate`]: run one
//! (program, co-design) pair under the coarse-grain estimator or under the
//! detailed board emulator respectively.

pub mod dma;
pub mod engine;
pub mod estimator;
pub mod time;

use crate::board::BoardModel;
use crate::config::{BoardConfig, CoDesign};
use crate::coordinator::deps::DepGraph;
use crate::coordinator::elaborate::ElabProgram;
use crate::coordinator::sched::Policy;
use crate::coordinator::task::TaskProgram;
use crate::hls::FpgaPart;

pub use engine::{
    resolve_codesign, AccelInstance, DeviceLabel, SegKind, Segment, SimResult, Simulator,
    TaskCtx, TimingModel,
};
pub use estimator::EstimatorModel;

/// Run a program under a co-design with an arbitrary timing model.
pub fn simulate(
    program: &TaskProgram,
    codesign: &CoDesign,
    board: &BoardConfig,
    part: &FpgaPart,
    policy: Policy,
    timing: &mut dyn TimingModel,
) -> anyhow::Result<SimResult> {
    let graph = DepGraph::build(program);
    let elab = ElabProgram::build(program, &graph);
    let (accels, smp_eligible) = resolve_codesign(program, codesign, board, part)?;
    let sim = Simulator::new(program, &elab, board, &accels, &smp_eligible, policy);
    Ok(sim.run(timing))
}

/// Run under the coarse-grain estimator (the paper's tool).
pub fn estimate(
    program: &TaskProgram,
    codesign: &CoDesign,
    board: &BoardConfig,
) -> anyhow::Result<SimResult> {
    let mut model = EstimatorModel::new(board);
    simulate(
        program,
        codesign,
        board,
        &FpgaPart::xc7z045(),
        Policy::Greedy,
        &mut model,
    )
}

/// Run under the detailed board emulator (the "real execution" stand-in).
pub fn emulate(
    program: &TaskProgram,
    codesign: &CoDesign,
    board: &BoardConfig,
) -> anyhow::Result<SimResult> {
    let mut model = BoardModel::new(board);
    simulate(
        program,
        codesign,
        board,
        &FpgaPart::xc7z045(),
        Policy::Greedy,
        &mut model,
    )
}

/// Run the board emulator `reps` times with distinct seeds and return the
/// mean makespan in ms — mirroring the paper's "average elapsed execution
/// time of 10 application executions".
pub fn emulate_mean_ms(
    program: &TaskProgram,
    codesign: &CoDesign,
    board: &BoardConfig,
    reps: u32,
) -> anyhow::Result<f64> {
    let mut total = 0.0;
    for i in 0..reps {
        let mut b = board.clone();
        b.emu.seed = board.emu.seed.wrapping_add(i as u64 * 0x9E37_79B9);
        let r = emulate(program, codesign, &b)?;
        total += r.makespan_ms();
    }
    Ok(total / reps as f64)
}
