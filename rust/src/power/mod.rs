//! Power and energy estimation — the paper's §VII future work ("integrate
//! power-efficiency ... into the simulator"), implemented as a first-class
//! extension.
//!
//! The model is the standard platform-level decomposition used by the ESL
//! estimation work the paper cites ([11], [19]): per-device *static* power
//! whenever the platform is on, plus *dynamic* power while a device is
//! busy, integrated over the simulated timeline. Constants default to
//! public Zynq-7045 numbers (XPE-era): PS ≈ 1.5 W static + ~0.7 W/core
//! dynamic; fabric static ≈ 0.25 W plus leakage proportional to the
//! configured area; accelerator dynamic power scales with DSP/BRAM/LUT
//! usage and clock; DMA engines a few hundred mW while streaming.
//!
//! Output: energy per configuration and the energy-delay product, so the
//! co-design sweep can rank by performance, energy, or EDP — which flips
//! winners exactly the way the future-work section anticipates.

use crate::hls::Resources;
use crate::sim::engine::{DeviceLabel, SimResult};

/// Platform power constants (watts). See module docs for provenance.
#[derive(Clone, Debug, PartialEq)]
pub struct PowerModel {
    /// PS static power (regulators, DDR PHY, always-on).
    pub ps_static_w: f64,
    /// Per-A9-core dynamic power while executing.
    pub smp_dynamic_w: f64,
    /// PL static power for the configured device (leakage floor).
    pub pl_static_w: f64,
    /// PL leakage per 1% of fabric utilization.
    pub pl_static_per_util_w: f64,
    /// Dynamic watts per active DSP slice at 100 MHz (scaled by clock).
    pub w_per_dsp_100mhz: f64,
    /// Dynamic watts per active BRAM18 at 100 MHz.
    pub w_per_bram_100mhz: f64,
    /// Dynamic watts per 10k LUTs at 100 MHz.
    pub w_per_10kluts_100mhz: f64,
    /// DMA engine power while a channel streams.
    pub dma_dynamic_w: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        Self {
            ps_static_w: 1.5,
            smp_dynamic_w: 0.7,
            pl_static_w: 0.25,
            pl_static_per_util_w: 0.006,
            w_per_dsp_100mhz: 0.0023,
            w_per_bram_100mhz: 0.0028,
            w_per_10kluts_100mhz: 0.012,
            dma_dynamic_w: 0.35,
        }
    }
}

/// Energy report for one simulated configuration.
#[derive(Clone, Debug)]
pub struct EnergyReport {
    /// Simulated makespan, seconds.
    pub makespan_s: f64,
    /// Static (always-on) energy, joules.
    pub static_j: f64,
    /// ARM-core dynamic energy (incl. the DMA-submit software cost), joules.
    pub smp_dynamic_j: f64,
    /// Accelerator dynamic energy, joules.
    pub accel_dynamic_j: f64,
    /// DMA-channel dynamic energy, joules.
    pub dma_dynamic_j: f64,
}

impl EnergyReport {
    /// Total energy, joules.
    pub fn total_j(&self) -> f64 {
        self.static_j + self.smp_dynamic_j + self.accel_dynamic_j + self.dma_dynamic_j
    }

    /// Energy-delay product (J·s) — the metric that penalizes both slow
    /// and power-hungry co-designs.
    pub fn edp(&self) -> f64 {
        self.total_j() * self.makespan_s
    }

    /// Mean power over the run, watts.
    pub fn mean_power_w(&self) -> f64 {
        if self.makespan_s > 0.0 {
            self.total_j() / self.makespan_s
        } else {
            0.0
        }
    }
}

impl PowerModel {
    /// Dynamic power of one accelerator instance while busy.
    pub fn accel_dynamic_w(&self, res: &Resources, fmax_mhz: f64) -> f64 {
        let clock_scale = fmax_mhz / 100.0;
        clock_scale
            * (res.dsps as f64 * self.w_per_dsp_100mhz
                + res.bram18 as f64 * self.w_per_bram_100mhz
                + res.luts as f64 / 10_000.0 * self.w_per_10kluts_100mhz)
    }

    /// Integrate energy over a simulation result. `accel_resources[i]` is
    /// the resource vector of accelerator instance `i`; `fabric_util` the
    /// total PL utilization of the co-design in [0, 1].
    pub fn energy(
        &self,
        result: &SimResult,
        accel_resources: &[Resources],
        fabric_util: f64,
        fabric_mhz: f64,
    ) -> EnergyReport {
        let makespan_s = result.makespan as f64 / 1e12;
        let pl_static =
            self.pl_static_w + self.pl_static_per_util_w * (fabric_util * 100.0);
        let static_j = (self.ps_static_w + pl_static) * makespan_s;

        let mut smp_dynamic_j = 0.0;
        let mut accel_dynamic_j = 0.0;
        let mut dma_dynamic_j = 0.0;
        for (dev, busy_ps) in &result.device_busy {
            let busy_s = *busy_ps as f64 / 1e12;
            match dev {
                DeviceLabel::Smp(_) => smp_dynamic_j += self.smp_dynamic_w * busy_s,
                DeviceLabel::Accel(i) => {
                    let res = accel_resources
                        .get(*i as usize)
                        .copied()
                        .unwrap_or(Resources::ZERO);
                    accel_dynamic_j += self.accel_dynamic_w(&res, fabric_mhz) * busy_s;
                }
                DeviceLabel::DmaSubmit => smp_dynamic_j += self.smp_dynamic_w * 0.3 * busy_s,
                DeviceLabel::DmaChan(_) => dma_dynamic_j += self.dma_dynamic_w * busy_s,
            }
        }
        EnergyReport {
            makespan_s,
            static_j,
            smp_dynamic_j,
            accel_dynamic_j,
            dma_dynamic_j,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::matmul::{self, Matmul};
    use crate::config::BoardConfig;
    use crate::hls::{CostModel, FpgaPart};
    use crate::sim::estimate;

    fn energy_of(cd_name: &str) -> EnergyReport {
        let board = BoardConfig::zynq706();
        let (cd, app) = matmul::fig5_cases(512)
            .into_iter()
            .find(|(cd, _)| cd.name == cd_name)
            .unwrap();
        let p = app.build_program(&board);
        let res = estimate(&p, &cd, &board).unwrap();
        let cm = CostModel::from_board(&board);
        let resources: Vec<Resources> = cd
            .accels
            .iter()
            .map(|a| {
                let kid = p.kernel_id(&a.kernel).unwrap();
                cm.estimate(&a.kernel, &p.kernel(kid).profile, a.unroll)
                    .resources
            })
            .collect();
        let util = FpgaPart::xc7z045().utilization(&resources);
        PowerModel::default().energy(&res, &resources, util, board.fabric_freq_mhz)
    }

    #[test]
    fn energy_components_positive_and_consistent() {
        let e = energy_of("1acc 128");
        assert!(e.static_j > 0.0);
        assert!(e.accel_dynamic_j > 0.0);
        assert!(e.total_j() >= e.static_j);
        assert!(e.mean_power_w() > 1.5, "must exceed PS static");
        assert!(e.mean_power_w() < 15.0, "implausible for a Zynq board");
    }

    #[test]
    fn fpga_only_beats_smp_heavy_on_energy() {
        // The heterogeneous config burns both A9 cores for 5x longer —
        // it must lose on energy, not just time.
        let fpga = energy_of("1acc 128");
        let smp = energy_of("1acc 128 + smp");
        assert!(fpga.total_j() < smp.total_j());
        assert!(fpga.edp() < smp.edp());
    }

    #[test]
    fn accel_power_scales_with_area_and_clock() {
        let pm = PowerModel::default();
        let small = Resources {
            luts: 10_000,
            ffs: 20_000,
            dsps: 100,
            bram18: 50,
        };
        let big = Resources {
            luts: 60_000,
            ffs: 120_000,
            dsps: 600,
            bram18: 300,
        };
        assert!(pm.accel_dynamic_w(&big, 125.0) > pm.accel_dynamic_w(&small, 125.0));
        assert!(pm.accel_dynamic_w(&small, 250.0) > pm.accel_dynamic_w(&small, 125.0));
    }

    #[test]
    fn static_energy_grows_with_makespan() {
        let fast = energy_of("1acc 128");
        let slow = energy_of("1acc 64");
        assert!(slow.makespan_s > fast.makespan_s);
        assert!(slow.static_j > fast.static_j);
    }
}
