//! Design-space exploration — the paper defers this ("a design space
//! exploration strategy should be analyzed to reduce the amount of
//! possible solutions", §I; "explore different design space exploration
//! strategies", §VII). Because the estimator evaluates a configuration in
//! milliseconds, plain enumeration over the feasible co-design space is
//! practical for the paper's app sizes; that is what this module does,
//! with multi-objective ranking (time / energy / EDP) and a Pareto front.
//!
//! Evaluation runs through the [`sweep`] engine: a shared [`SweepContext`]
//! (one-time dependence graph + elaboration + memoized HLS reports) and
//! parallel, deterministic point evaluation. The [`prune`] module cuts the
//! cartesian space *before* evaluation (resource, dominance and
//! lower-bound cuts — lossless for the best point and the Pareto front —
//! with selectable round ordering, [`OrderMode`]), the [`warm`] module
//! carries evaluations *across* sweeps (a persistent two-level
//! [`EvalMemo`]: exact per-context memo hits skip re-simulation
//! bit-identically and seed the bound frontier, while a per-kernel
//! sub-memo shares HLS reports and occupancy priors across program sizes
//! and sibling boards, with `stats`/`gc`/`compact` hygiene keeping
//! long-lived files bounded), [`SweepSuite`] batches several applications
//! through one shared worker pool — warm or cold — and
//! [`cross::CrossBoardSweep`] makes the *platform* a swept axis:
//! a [`crate::board::BoardSpace`] of named (board, FPGA part) candidates
//! expands into per-board contexts with per-board caches and bound
//! frontiers, digested by [`cross::board_winner_table`] into "which board
//! wins at which budget". The free functions here are thin wrappers kept
//! for the CLI/tests; long-lived callers should build a `SweepContext`
//! themselves and reuse it.

pub mod ckpt;
pub mod cross;
pub mod prune;
pub mod sweep;
pub mod warm;

use std::collections::BTreeMap;

use crate::config::{BoardConfig, CoDesign};
use crate::coordinator::task::TaskProgram;
use crate::hls::FpgaPart;

pub use ckpt::{CheckpointJob, RecoverySession, SweepCheckpoint};
pub use cross::{
    board_winner_table, board_winner_table_for, BudgetAxis, BudgetRow, CrossBoardResult,
    CrossBoardSweep,
};
pub use prune::{enumerate_pruned, OrderMode, PruneStats, SweepCancelled};
pub use sweep::{
    default_workers, DeltaStats, SuiteApp, SuiteAppResult, SweepContext, SweepSuite, SweepWorker,
};
pub use warm::{EvalMemo, GcReport, MemoContextStat, MemoStats, SweepJournal, WalRecovery};

/// Exploration space for one kernel.
#[derive(Clone, Debug)]
pub struct KernelSpace {
    /// Kernel name (must match a program kernel to contribute options).
    pub kernel: String,
    /// Candidate unroll factors (HLS variants).
    pub unrolls: Vec<u32>,
    /// Maximum number of accelerator instances to consider.
    pub max_instances: u32,
    /// Whether to also consider "+ smp" heterogeneous execution.
    pub try_smp: bool,
}

/// The whole space: one entry per FPGA-capable kernel.
#[derive(Clone, Debug, Default)]
pub struct DseSpace {
    /// Per-kernel sub-spaces; the full space is their cartesian product.
    pub kernels: Vec<KernelSpace>,
    /// Mixed-variant enumeration: when set, a kernel's accelerator
    /// instances may use *different* unroll variants (every multiset of
    /// variants up to `max_instances`), instead of the homogeneous
    /// `count × same-unroll` options. Grows the per-kernel option count
    /// from `unrolls × max_instances` to `Σ_c C(unrolls+c-1, c)` — the
    /// combinatorial regime the dominance/bound cuts and the warm-start
    /// layer are stress-tested against.
    pub mixed: bool,
}

impl DseSpace {
    /// Derive a default space from a program: every FPGA-annotated kernel,
    /// unrolls {8, 16, 32, 64}, up to 2 instances, optional smp.
    pub fn from_program(program: &TaskProgram) -> Self {
        let kernels = program
            .kernels
            .iter()
            .filter(|k| k.targets.fpga)
            .map(|k| KernelSpace {
                kernel: k.name.clone(),
                unrolls: vec![8, 16, 32, 64],
                max_instances: 2,
                try_smp: k.targets.smp,
            })
            .collect();
        Self {
            kernels,
            mixed: false,
        }
    }

    /// Builder: switch the space to mixed-variant enumeration.
    pub fn with_mixed(mut self) -> Self {
        self.mixed = true;
        self
    }
}

/// Index multisets over `n_variants` per-kernel accelerator variants, in
/// the canonical per-kernel option order shared by the exhaustive
/// ([`SweepContext::enumerate`]) and pruned ([`prune`]) enumerations (the
/// empty option is *not* included — callers prepend it):
///
/// * homogeneous (`mixed == false`): variant-major, count-minor —
///   `[v]`, `[v, v]`, … for each variant `v` in order (the historical
///   order, kept bit-compatible);
/// * mixed: count-major, then lexicographic non-decreasing index
///   sequences — `[0]`, `[1]`, …, `[0,0]`, `[0,1]`, …
///
/// Both paths map surviving (non-dominated, deduplicated) variants through
/// the same function, so the pruned candidate list stays a subsequence of
/// the exhaustive one in the same relative order.
pub(crate) fn variant_multisets(
    n_variants: usize,
    max_instances: u32,
    mixed: bool,
) -> Vec<Vec<usize>> {
    let mut out = Vec::new();
    if n_variants == 0 {
        return out;
    }
    if !mixed {
        for v in 0..n_variants {
            for count in 1..=max_instances {
                out.push(vec![v; count as usize]);
            }
        }
        return out;
    }
    for count in 1..=max_instances {
        let mut cur = vec![0usize; count as usize];
        loop {
            out.push(cur.clone());
            // Advance the non-decreasing odometer: bump the rightmost
            // index that still can, and reset the tail to its new value.
            let mut level = cur.len();
            loop {
                if level == 0 {
                    break;
                }
                let i = level - 1;
                if cur[i] + 1 < n_variants {
                    cur[i] += 1;
                    let v = cur[i];
                    for slot in cur.iter_mut().skip(i + 1) {
                        *slot = v;
                    }
                    break;
                }
                level -= 1;
            }
            if level == 0 {
                break;
            }
        }
    }
    out
}

/// Ranking objective.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    /// Estimated makespan (ms).
    Time,
    /// Total platform energy (J).
    Energy,
    /// Energy-delay product (J·s).
    Edp,
}

impl Objective {
    /// Parse a CLI objective name (`time` | `energy` | `edp`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "time" => Some(Objective::Time),
            "energy" => Some(Objective::Energy),
            "edp" => Some(Objective::Edp),
            _ => None,
        }
    }

    /// The CLI/protocol name this objective parses back from —
    /// `Objective::parse(o.as_str()) == Some(o)` for every variant.
    pub fn as_str(&self) -> &'static str {
        match self {
            Objective::Time => "time",
            Objective::Energy => "energy",
            Objective::Edp => "edp",
        }
    }
}

/// One evaluated design point.
#[derive(Clone, Debug)]
pub struct DsePoint {
    /// The co-design that was simulated.
    pub codesign: CoDesign,
    /// Estimated makespan in milliseconds.
    pub est_ms: f64,
    /// Estimated total platform energy in joules.
    pub energy_j: f64,
    /// Energy-delay product in J·s.
    pub edp: f64,
    /// Programmable-logic utilization of the accelerator mix, in [0, 1].
    pub fabric_util: f64,
}

impl DsePoint {
    /// The point's value under a ranking objective (lower is better).
    pub fn score(&self, obj: Objective) -> f64 {
        match obj {
            Objective::Time => self.est_ms,
            Objective::Energy => self.energy_j,
            Objective::Edp => self.edp,
        }
    }
}

/// Outcome of one candidate evaluation in a pruned sweep round.
///
/// The sweep engine evaluates every point under `catch_unwind`: a
/// panicking candidate is recorded as [`PointOutcome::Poisoned`] — counted
/// in [`PruneStats::poisoned`], excluded from bound frontiers, rankings
/// and the persistent memo — instead of aborting the whole sweep. Whether
/// a candidate poisons is a deterministic property of the point itself
/// (never of thread scheduling), so the poisoned set is identical for any
/// worker count.
#[derive(Clone, Debug)]
pub enum PointOutcome {
    /// The point evaluated normally.
    Evaluated(DsePoint),
    /// The evaluation panicked and was quarantined.
    Poisoned,
}

impl PointOutcome {
    /// The evaluated point, if the evaluation did not panic.
    pub fn point(&self) -> Option<&DsePoint> {
        match self {
            PointOutcome::Evaluated(p) => Some(p),
            PointOutcome::Poisoned => None,
        }
    }

    /// Consume the outcome into its evaluated point, if any.
    pub fn into_point(self) -> Option<DsePoint> {
        match self {
            PointOutcome::Evaluated(p) => Some(p),
            PointOutcome::Poisoned => None,
        }
    }
}

/// Enumerate feasible co-designs over the space (resource-pruned).
///
/// Thin wrapper: builds a primed [`SweepContext`] and delegates. Callers
/// that also evaluate points should build the context once and reuse it.
pub fn enumerate(
    program: &TaskProgram,
    board: &BoardConfig,
    part: &FpgaPart,
    space: &DseSpace,
) -> Vec<CoDesign> {
    SweepContext::for_space(program, board, part, space).enumerate(space)
}

fn describe(cd: &CoDesign) -> String {
    if cd.accels.is_empty() {
        return "smp-only".to_string();
    }
    let mut counts: BTreeMap<String, u32> = BTreeMap::new();
    for a in &cd.accels {
        *counts.entry(format!("{}:U{}", a.kernel, a.unroll)).or_insert(0) += 1;
    }
    let mut s = counts
        .iter()
        .map(|(k, c)| format!("{c}x{k}"))
        .collect::<Vec<_>>()
        .join(" + ");
    if !cd.smp_kernels.is_empty() {
        s.push_str(" +smp");
    }
    s
}

/// Evaluate every feasible point and rank by the objective.
///
/// Runs the shared-context sweep engine with one worker per available
/// core; the output is bit-identical to a serial sweep (see
/// `dse::sweep`). Use [`SweepContext::explore`] directly to control the
/// worker count or amortize the context across multiple spaces.
pub fn explore(
    program: &TaskProgram,
    board: &BoardConfig,
    part: &FpgaPart,
    space: &DseSpace,
    objective: Objective,
) -> anyhow::Result<Vec<DsePoint>> {
    let ctx = SweepContext::for_space(program, board, part, space);
    Ok(ctx.explore(space, objective, default_workers()))
}

/// Time-energy coordinates of the Pareto front of a ranked point list, as
/// exact `f64` bit patterns, sorted and deduplicated — the canonical form
/// for comparing fronts across sweeps (used by the pruning-soundness tests
/// and the suite harness).
pub fn pareto_front_coords(points: &[DsePoint]) -> Vec<(u64, u64)> {
    let mut f: Vec<(u64, u64)> = pareto_front(points)
        .into_iter()
        .map(|i| (points[i].est_ms.to_bits(), points[i].energy_j.to_bits()))
        .collect();
    f.sort_unstable();
    f.dedup();
    f
}

/// Indices of the coordinates not strictly dominated in the
/// minimize-both sense (no other point is `<=` in both axes and `<` in
/// one) — the one dominance filter behind every front in the crate
/// (time-energy, utilization-time, the memo's serialized frontiers).
pub(crate) fn front_indices(coords: &[(f64, f64)]) -> Vec<usize> {
    let mut front = Vec::new();
    for (i, &(x, y)) in coords.iter().enumerate() {
        let dominated = coords
            .iter()
            .enumerate()
            .any(|(j, &(x2, y2))| j != i && x2 <= x && y2 <= y && (x2 < x || y2 < y));
        if !dominated {
            front.push(i);
        }
    }
    front
}

/// Indices of the time-energy Pareto-optimal points.
pub fn pareto_front(points: &[DsePoint]) -> Vec<usize> {
    let coords: Vec<(f64, f64)> = points.iter().map(|p| (p.est_ms, p.energy_j)).collect();
    front_indices(&coords)
}

/// Render the exploration as a table.
pub fn render(points: &[DsePoint], top: usize, objective: Objective) -> String {
    let front = pareto_front(points);
    let mut out = format!(
        "== DSE: {} feasible co-designs, ranked by {:?} (P = time-energy Pareto)\n",
        points.len(),
        objective
    );
    out.push_str(&format!(
        "{:>4} {:>2}  {:36} {:>10} {:>10} {:>12} {:>6}\n",
        "#", "", "co-design", "time (ms)", "energy (J)", "EDP (mJ*s)", "util"
    ));
    for (i, p) in points.iter().take(top).enumerate() {
        out.push_str(&format!(
            "{:>4} {:>2}  {:36} {:>10.2} {:>10.3} {:>12.3} {:>5.0}%\n",
            i + 1,
            if front.contains(&i) { "P" } else { "" },
            p.codesign.name,
            p.est_ms,
            p.energy_j,
            p.edp * 1e3,
            p.fabric_util * 100.0
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{cholesky::Cholesky, matmul::Matmul};

    #[test]
    fn enumerate_prunes_infeasible() {
        let board = BoardConfig::zynq706();
        let p = Matmul::new(512, 128).build_program(&board);
        let space = DseSpace {
            kernels: vec![KernelSpace {
                kernel: "mxm128".into(),
                unrolls: vec![64, 128],
                max_instances: 2,
                try_smp: true,
            }],
            mixed: false,
        };
        let cds = enumerate(&p, &board, &FpgaPart::xc7z045(), &space);
        // 2x U128 must be pruned (paper feasibility); smp-only kept.
        assert!(cds.iter().any(|c| c.accels.is_empty()));
        assert!(!cds
            .iter()
            .any(|c| c.accel_count_for("mxm128") == 2
                && c.accels.iter().all(|a| a.unroll == 128)));
        assert!(cds.iter().any(|c| c.accel_count_for("mxm128") == 1
            && c.accels[0].unroll == 128));
    }

    #[test]
    fn explore_matmul_beats_the_papers_fixed_set() {
        // The paper's programmer only considered one full-unroll 128x128
        // accelerator (two do not fit). The DSE discovers a point outside
        // that fixed set: *two half-unroll* 128-block accelerators — they
        // fit, and because input DMA channels scale with accelerators
        // (Fig. 3), they outperform the single U128 instance. Exactly the
        // kind of result §I/§VII say automated exploration should bring.
        let board = BoardConfig::zynq706();
        let p = Matmul::new(512, 128).build_program(&board);
        let space = DseSpace {
            kernels: vec![KernelSpace {
                kernel: "mxm128".into(),
                unrolls: vec![32, 64, 128],
                max_instances: 2,
                try_smp: true,
            }],
            mixed: false,
        };
        let pts = explore(&p, &board, &FpgaPart::xc7z045(), &space, Objective::Time).unwrap();
        assert!(!pts.is_empty());
        let best = &pts[0];
        // FPGA-only wins (never "+smp" under the greedy policy).
        assert!(best.codesign.smp_kernels.is_empty(), "{}", best.codesign.name);
        // And it beats the paper's choice (1x U128).
        let paper_choice = pts
            .iter()
            .find(|pt| {
                pt.codesign.accel_count_for("mxm128") == 1
                    && pt.codesign.accels[0].unroll == 128
                    && pt.codesign.smp_kernels.is_empty()
            })
            .expect("paper's co-design must be in the space");
        assert!(
            best.est_ms <= paper_choice.est_ms,
            "DSE best {} ({:.1} ms) must be <= paper choice ({:.1} ms)",
            best.codesign.name,
            best.est_ms,
            paper_choice.est_ms
        );
        assert_eq!(
            best.codesign.accel_count_for("mxm128"),
            2,
            "expected the 2x half-unroll discovery, got {}",
            best.codesign.name
        );
    }

    #[test]
    fn cholesky_default_space_explores_pairs() {
        let board = BoardConfig::zynq706();
        let p = Cholesky::new(256, 64).build_program(&board);
        let space = DseSpace::from_program(&p);
        // dpotrf is SMP-only, so the space covers dgemm/dsyrk/dtrsm.
        assert_eq!(space.kernels.len(), 3);
        let pts = explore(&p, &board, &FpgaPart::xc7z045(), &space, Objective::Edp).unwrap();
        assert!(pts.len() > 10, "space too small: {}", pts.len());
        // EDP ordering is monotone in score.
        for w in pts.windows(2) {
            assert!(w[0].edp <= w[1].edp);
        }
    }

    #[test]
    fn pareto_front_is_nondominated() {
        let board = BoardConfig::zynq706();
        let p = Matmul::new(512, 64).build_program(&board);
        let space = DseSpace::from_program(&p);
        let pts = explore(&p, &board, &FpgaPart::xc7z045(), &space, Objective::Time).unwrap();
        let front = pareto_front(&pts);
        assert!(!front.is_empty());
        for &i in &front {
            for (j, q) in pts.iter().enumerate() {
                if j == i {
                    continue;
                }
                let p_i = &pts[i];
                assert!(
                    !(q.est_ms < p_i.est_ms && q.energy_j < p_i.energy_j),
                    "front point {i} dominated by {j}"
                );
            }
        }
    }

    #[test]
    fn render_lists_points() {
        let board = BoardConfig::zynq706();
        let p = Matmul::new(512, 64).build_program(&board);
        let space = DseSpace::from_program(&p);
        let pts = explore(&p, &board, &FpgaPart::xc7z045(), &space, Objective::Time).unwrap();
        let s = render(&pts, 10, Objective::Time);
        assert!(s.contains("feasible co-designs"));
        assert!(s.contains("mxm64"));
    }
}
