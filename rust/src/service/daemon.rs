//! The resident estimator daemon behind `zynq-estimator serve`.
//!
//! One [`Service`] owns one shared [`EvalMemo`] and answers NDJSON
//! requests from any number of transports concurrently: the process's
//! stdin/stdout pair and (with `--listen`) a TCP listener where every
//! connection speaks the same one-line-per-message protocol. All
//! transports funnel into [`Service::handle_line`], so the daemon's
//! semantics are transport-independent and the conformance suite can
//! drive the cheap pipe transport and trust the TCP one.
//!
//! **Lane sharding.** With `--lanes N` the single memo lane of the
//! original daemon splits into N lanes keyed by application name:
//! requests for distinct apps acquire independent lane locks and run
//! their program analysis and cold evaluations concurrently under a
//! shared memo *read* lock, taking the write lock only for the brief
//! per-point bookkeeping. Apps are kernel-disjoint, so contexts that
//! share level-1 kernel state (the same app at several problem sizes)
//! always land in one lane and see exactly the sequential warmth
//! counters — which is what keeps every response byte-identical to the
//! single-lane daemon for any interleaving. Each lane journals to its
//! own WAL shard (`<memo>.wal`, `<memo>.wal.1`, ...), so the
//! crash-safety contract — lose at most the in-flight round — holds
//! independently per lane.
//!
//! **Batch evaluation.** The cold points of a `batch` envelope (and of a
//! `--batch-window-ms` accumulation window) are evaluated together as
//! one chunk-synchronous worker-pool round per context
//! ([`super::query::pre_evaluate`]), then each item's memo bookkeeping
//! and response rendering runs in original arrival order
//! ([`super::query::point_query_prepared`]). Evaluation is a pure
//! function of (context, co-design), so batching changes throughput and
//! never bytes; the conformance suite proves the responses equal the
//! sequential ones.
//!
//! **Coalescing.** Identical in-flight queries (same canonical
//! [`Envelope::coalesce_key`]) share one evaluation: the first arrival
//! becomes the *leader* and computes; later arrivals park on a condvar
//! and receive a clone of the leader's reply, so all N responses are
//! bitwise identical and the memo sees one recording. Coalescing is
//! observable only through the cumulative `coalesced` counter of
//! `{"req":"memo","action":"stats"}` — deliberately not in per-response
//! fields, which would break response bit-identity.
//!
//! **Persistence.** With `--memo <file>` the memo loads with WAL
//! recovery (all shards) at startup, journals every fresh evaluation as
//! a committed WAL round *before* its response is written, and saves
//! atomically every `--save-every` fresh evaluations, at `memo gc`, and
//! at shutdown/EOF. A `kill -9` therefore loses at most the in-flight
//! round per lane — the same contract the recoverable sweeps have. A
//! failed save degrades cleanly: the daemon keeps answering, the shard
//! WALs keep the delta, and the final exit code turns non-zero so
//! supervisors notice.

use std::collections::{BTreeMap, HashMap};
use std::io::{BufRead, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{
    Arc, Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard,
};

use crate::config::BoardConfig;
use crate::coordinator::task::TaskProgram;
use crate::dse::warm::context_fingerprint;
use crate::dse::{EvalMemo, SweepContext, SweepJournal};
use crate::hls::FpgaPart;
use crate::util::fnv::Fnv;
use crate::util::json::Value;

use super::proto::{
    err_line, err_obj, ok_line, ok_obj, parse_request, BatchItem, Envelope, PointQuery,
    QueryReply, RequestKind, ServiceError,
};
use super::query::{
    dse_query, point_query_prepared, pre_evaluate, space_for_codesign, PreEvaluated,
};

/// Daemon configuration (the `serve` CLI flags).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Persistent memo file; `None` serves from a process-local memo.
    pub memo_path: Option<PathBuf>,
    /// TCP listen address (e.g. `127.0.0.1:0`); `None` is stdio-only.
    pub listen: Option<String>,
    /// Sweep worker threads (0 → one per core).
    pub workers: usize,
    /// Save the memo after this many fresh evaluations.
    pub save_every: u64,
    /// Byte budget enforced (via `EvalMemo::gc_bytes`) before each save.
    pub max_bytes: Option<usize>,
    /// Per-app most-recent context floor of the byte-budget gc.
    pub app_floor: usize,
    /// Memo lanes (`--lanes`): point/dse requests shard by app name and
    /// distinct lanes evaluate concurrently. `1` is the original
    /// single-lane daemon, bit for bit.
    pub lanes: usize,
    /// Accumulation window (`--batch-window-ms`) for cross-request batch
    /// evaluation of point queries; `0` disables the window (explicit
    /// `batch` envelopes always batch).
    pub batch_window_ms: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            memo_path: None,
            listen: None,
            workers: 0,
            save_every: 8,
            max_bytes: None,
            app_floor: 1,
            lanes: 1,
            batch_window_ms: 0,
        }
    }
}

/// Per-lane mutable state: the lane's shard journal. The lane lock is
/// what serializes requests that share memo state (same app), so holding
/// it across one request's evaluate-then-record sequence is exactly the
/// sequential semantics the byte-identity contract needs.
struct LaneState {
    journal: Option<SweepJournal>,
}

/// The accumulation window of one lane: point queries parked here are
/// drained by the window leader into one batch round.
#[derive(Default)]
struct Window {
    pending: Vec<PendingPoint>,
    collecting: bool,
}

/// One window-parked point query and the cell its reply is fanned into.
struct PendingPoint {
    query: PointQuery,
    energy: bool,
    cell: Arc<InFlight>,
}

/// A query in flight: the leader publishes into `slot` and wakes waiters.
struct InFlight {
    slot: Mutex<Option<Result<QueryReply, ServiceError>>>,
    done: Condvar,
}

impl InFlight {
    fn new() -> Self {
        InFlight {
            slot: Mutex::new(None),
            done: Condvar::new(),
        }
    }
}

/// Cumulative service counters (all monotonic, relaxed ordering — they
/// are observability, not synchronization).
#[derive(Default)]
struct Counters {
    requests: AtomicU64,
    coalesced: AtomicU64,
    batched: AtomicU64,
    evaluated: AtomicU64,
    l1_hits: AtomicU64,
    l2_hits: AtomicU64,
    errors: AtomicU64,
    saves: AtomicU64,
}

/// The resident estimator service: shared memo behind a read/write lock,
/// app-sharded lanes with per-shard journals, program and fingerprint
/// caches, in-flight coalescing table and counters. Wrap in an [`Arc`]
/// and call [`Service::handle_line`] from any number of threads.
pub struct Service {
    board: BoardConfig,
    part: FpgaPart,
    cfg: ServeConfig,
    programs: Mutex<BTreeMap<(String, u64, u64), Arc<TaskProgram>>>,
    /// The shared two-level memo. Evaluation and program analysis run
    /// under the *read* lock (so distinct lanes overlap); only the brief
    /// per-point bookkeeping and gc take the write lock.
    memo: RwLock<EvalMemo>,
    /// Cached context fingerprints per (app, n, bs) — the fingerprint
    /// covers program/board/part only, so it is computed once per context
    /// lifetime with a probe analysis and reused ever after.
    fingerprints: Mutex<BTreeMap<(String, u64, u64), u64>>,
    lanes: Vec<Mutex<LaneState>>,
    windows: Vec<Mutex<Window>>,
    inflight: Mutex<HashMap<String, Arc<InFlight>>>,
    /// Serializes savers; lane locks are only held *inside* a save.
    save_lock: Mutex<()>,
    fresh_since_save: AtomicU64,
    save_failed: AtomicBool,
    counters: Counters,
    shutdown: AtomicBool,
    exit_code: Mutex<Option<i32>>,
}

/// Lock that survives a poisoned-by-panic peer: a leader panicking
/// mid-query (fault injection does this on purpose) must not wedge the
/// daemon — worst case the memo lost one partial recording, which the
/// next save rewrites consistently.
fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// [`lock_unpoisoned`] for the memo read lock.
fn read_unpoisoned<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|p| p.into_inner())
}

/// [`lock_unpoisoned`] for the memo write lock.
fn write_unpoisoned<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|p| p.into_inner())
}

impl Service {
    /// Build the service: load the memo (with WAL recovery across every
    /// shard journal) and open one shard journal per lane. Startup
    /// diagnostics go to stderr — stdout carries only NDJSON responses.
    pub fn new(board: BoardConfig, cfg: ServeConfig) -> anyhow::Result<Self> {
        let n_lanes = cfg.lanes.max(1);
        let mut journals: Vec<Option<SweepJournal>> = (0..n_lanes).map(|_| None).collect();
        let memo = match &cfg.memo_path {
            Some(path) => {
                let (memo, recovered) = EvalMemo::load_with_recovery(path)?;
                if let Some(rec) = &recovered {
                    eprintln!(
                        "serve: recovered {} journaled points across {} contexts \
                         ({} committed rounds) from the journal(s) of {}",
                        rec.n_points(),
                        rec.contexts.len(),
                        rec.rounds,
                        path.display(),
                    );
                }
                eprintln!(
                    "serve: memo {} ({} contexts, {} points, {} kernel entries)",
                    path.display(),
                    memo.n_contexts(),
                    memo.n_points(),
                    memo.n_kernel_entries(),
                );
                for (shard, slot) in journals.iter_mut().enumerate() {
                    *slot = Some(SweepJournal::open_shard(path, shard)?);
                }
                memo
            }
            None => EvalMemo::new(),
        };
        Ok(Service {
            board,
            part: FpgaPart::xc7z045(),
            cfg,
            programs: Mutex::new(BTreeMap::new()),
            memo: RwLock::new(memo),
            fingerprints: Mutex::new(BTreeMap::new()),
            lanes: journals
                .into_iter()
                .map(|journal| Mutex::new(LaneState { journal }))
                .collect(),
            windows: (0..n_lanes).map(|_| Mutex::new(Window::default())).collect(),
            inflight: Mutex::new(HashMap::new()),
            save_lock: Mutex::new(()),
            fresh_since_save: AtomicU64::new(0),
            save_failed: AtomicBool::new(false),
            counters: Counters::default(),
            shutdown: AtomicBool::new(false),
            exit_code: Mutex::new(None),
        })
    }

    /// Total requests parsed (well-formed or not).
    pub fn requests(&self) -> u64 {
        self.counters.requests.load(Ordering::Relaxed)
    }

    /// Requests that joined another request's in-flight evaluation.
    pub fn coalesced(&self) -> u64 {
        self.counters.coalesced.load(Ordering::Relaxed)
    }

    /// Point queries answered through a batch round (explicit `batch`
    /// envelopes plus accumulation-window batches).
    pub fn batched(&self) -> u64 {
        self.counters.batched.load(Ordering::Relaxed)
    }

    /// Points freshly simulated across all queries.
    pub fn evaluated(&self) -> u64 {
        self.counters.evaluated.load(Ordering::Relaxed)
    }

    /// Error responses sent (including failed batch items).
    pub fn errors(&self) -> u64 {
        self.counters.errors.load(Ordering::Relaxed)
    }

    /// Number of memo lanes the service shards across.
    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    fn workers(&self) -> usize {
        match self.cfg.workers {
            0 => crate::dse::default_workers(),
            w => w,
        }
    }

    /// Lane of an app. Apps are kernel-disjoint, so hashing the app name
    /// keeps every context that shares level-1 kernel state (one app at
    /// several problem sizes) in one lane — which is what makes the
    /// per-response warmth counters deterministic under concurrency —
    /// while distinct apps spread across lanes and evaluate concurrently.
    fn lane_of(&self, app: &str) -> usize {
        let mut h = Fnv::new();
        h.str(app);
        (h.finish() % self.lanes.len() as u64) as usize
    }

    fn program(&self, app: &str, n: u64, bs: u64) -> Result<Arc<TaskProgram>, ServiceError> {
        let key = (app.to_string(), n, bs);
        if let Some(p) = lock_unpoisoned(&self.programs).get(&key) {
            return Ok(Arc::clone(p));
        }
        // Built outside the cache lock: program construction is pure.
        let program = crate::apps::build_app_program(app, n, bs, &self.board)
            .map_err(|e| ServiceError::usage(format!("{e:#}")))?;
        let program = Arc::new(program);
        lock_unpoisoned(&self.programs)
            .entry(key)
            .or_insert_with(|| Arc::clone(&program));
        Ok(program)
    }

    /// Context fingerprint of one (app, n, bs) context, cached. The
    /// fingerprint covers program/board/part only — never the swept
    /// space — so one probe analysis computes it and every later request
    /// (the hot path) reuses it without touching the program again.
    fn fingerprint(&self, program: &TaskProgram, key: &(String, u64, u64)) -> u64 {
        if let Some(fp) = lock_unpoisoned(&self.fingerprints).get(key) {
            return *fp;
        }
        let ctx = SweepContext::new(program, &self.board, self.part.clone());
        let fp = context_fingerprint(&ctx);
        lock_unpoisoned(&self.fingerprints).insert(key.clone(), fp);
        fp
    }

    /// Save the memo: serialize savers, quiesce every lane (all lane
    /// locks, ascending index order), close the shard journals (a
    /// successful save deletes the WAL files — keeping the handles would
    /// journal into deleted inodes), enforce the byte budget, save
    /// atomically, reopen the shard journals. On failure the daemon
    /// degrades instead of dying: the shard WALs still carry the delta
    /// and `save_failed` turns the final exit code non-zero.
    ///
    /// Callers must not hold any lane lock or memo guard.
    fn save_all(&self) {
        let Some(path) = self.cfg.memo_path.clone() else {
            self.fresh_since_save.store(0, Ordering::Relaxed);
            return;
        };
        let _saver = lock_unpoisoned(&self.save_lock);
        let mut lanes: Vec<_> = self.lanes.iter().map(lock_unpoisoned).collect();
        for lane in &mut lanes {
            lane.journal = None;
        }
        if let Some(max) = self.cfg.max_bytes {
            let gc = write_unpoisoned(&self.memo).gc_bytes(max, self.cfg.app_floor);
            if gc.evicted_contexts > 0 || gc.evicted_kernels > 0 {
                eprintln!(
                    "serve: byte-budget gc evicted {} contexts ({} points), {} kernel entries",
                    gc.evicted_contexts, gc.evicted_points, gc.evicted_kernels
                );
            }
        }
        match read_unpoisoned(&self.memo).save(&path) {
            Ok(()) => {
                self.fresh_since_save.store(0, Ordering::Relaxed);
                self.counters.saves.fetch_add(1, Ordering::Relaxed);
            }
            Err(e) => {
                self.save_failed.store(true, Ordering::Relaxed);
                eprintln!(
                    "serve: memo save failed ({e:#}) — continuing degraded; \
                     the WAL retains unsaved rounds"
                );
            }
        }
        if self.shutdown.load(Ordering::SeqCst) {
            // Final save on shutdown: leave the journals closed so a clean
            // exit leaves no WAL siblings behind (opening a shard journal
            // creates its file eagerly).
            return;
        }
        for (shard, lane) in lanes.iter_mut().enumerate() {
            match SweepJournal::open_shard(&path, shard) {
                Ok(j) => lane.journal = Some(j),
                Err(e) => eprintln!(
                    "serve: journal reopen failed for lane {shard} ({e:#}); \
                     journaling disabled"
                ),
            }
        }
    }

    /// Save when the fresh-evaluation cadence is due. Callers must not
    /// hold any lane lock or memo guard.
    fn maybe_save(&self) {
        if self.cfg.memo_path.is_some()
            && self.fresh_since_save.load(Ordering::Relaxed) >= self.cfg.save_every.max(1)
        {
            self.save_all();
        }
    }

    /// Warmth counters + save cadence for one answered query.
    fn bump_warmth(&self, reply: &QueryReply) {
        self.counters
            .evaluated
            .fetch_add(reply.evaluated, Ordering::Relaxed);
        self.counters
            .l1_hits
            .fetch_add(reply.l1_hits, Ordering::Relaxed);
        self.counters
            .l2_hits
            .fetch_add(reply.l2_hits, Ordering::Relaxed);
        self.fresh_since_save
            .fetch_add(reply.evaluated, Ordering::Relaxed);
    }

    /// Answer one point item against its lane: the context analysis runs
    /// under the shared memo read lock (concurrent across lanes), the
    /// bookkeeping under a brief write lock. A panicking evaluation
    /// (fault injection) answers an error instead of tearing the lane
    /// down.
    fn point_item(
        &self,
        program: &TaskProgram,
        q: &PointQuery,
        energy: bool,
        pre: &PreEvaluated,
        lane: &mut LaneState,
    ) -> Result<QueryReply, ServiceError> {
        let cd = q.codesign();
        let space = space_for_codesign(&cd);
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let ctx = {
                let memo = read_unpoisoned(&self.memo);
                SweepContext::for_space_warm(program, &self.board, &self.part, &space, &memo)
            };
            let mut memo = write_unpoisoned(&self.memo);
            point_query_prepared(
                &ctx,
                &space,
                &q.app,
                q.n,
                q.bs,
                &cd,
                energy,
                &mut memo,
                lane.journal.as_mut(),
                Some(pre),
            )
        }));
        match outcome {
            Ok(res) => res
                .map(|o| o.reply)
                .map_err(|e| ServiceError::usage(format!("{e:#}"))),
            Err(_) => Err(ServiceError::usage(
                "evaluation panicked (see stderr); request dropped",
            )),
        }
    }

    /// Answer the subset of `items` (by index) that belongs to one lane.
    /// Phase 1 runs one chunk-synchronous worker-pool round per context
    /// over its cold points, under the shared read lock; phase 2 performs
    /// each item's bookkeeping and rendering in original arrival order,
    /// which reproduces the sequential responses byte for byte.
    fn run_lane_items(
        &self,
        lane: &mut LaneState,
        items: &[(PointQuery, bool)],
        programs: &[Option<Arc<TaskProgram>>],
        idxs: &[usize],
        out: &mut [Option<Result<QueryReply, ServiceError>>],
    ) {
        let mut groups: Vec<((String, u64, u64), Vec<usize>)> = Vec::new();
        for &i in idxs {
            let q = &items[i].0;
            let key = (q.app.clone(), q.n, q.bs);
            match groups.iter_mut().find(|(k, _)| *k == key) {
                Some((_, members)) => members.push(i),
                None => groups.push((key, vec![i])),
            }
        }
        let workers = self.workers();
        let mut pres: Vec<PreEvaluated> = Vec::with_capacity(groups.len());
        for (key, members) in &groups {
            let program = programs[members[0]]
                .as_ref()
                .expect("grouped items resolved their program");
            let fp = self.fingerprint(program, key);
            let cds: Vec<_> = members.iter().map(|&i| items[i].0.codesign()).collect();
            let memo = read_unpoisoned(&self.memo);
            pres.push(pre_evaluate(
                program,
                &self.board,
                &self.part,
                fp,
                &cds,
                &memo,
                workers,
            ));
        }
        let mut group_of: BTreeMap<usize, usize> = BTreeMap::new();
        for (g, (_, members)) in groups.iter().enumerate() {
            for &i in members {
                group_of.insert(i, g);
            }
        }
        for &i in idxs {
            let (q, energy) = &items[i];
            let program = programs[i].as_ref().expect("lane items have programs");
            let res = self.point_item(program, q, *energy, &pres[group_of[&i]], lane);
            if let Ok(reply) = &res {
                self.bump_warmth(reply);
            }
            out[i] = Some(res);
        }
    }

    /// Answer a slice of point queries with cross-request batch
    /// evaluation. Items shard per lane (lanes are state-disjoint, so
    /// processing lanes in ascending index order is cosmetic); within a
    /// lane, each context's cold points run as one worker-pool round and
    /// every response is byte-identical to handling the items one
    /// request at a time in the same order.
    fn run_point_items(
        &self,
        items: &[(PointQuery, bool)],
    ) -> Vec<Result<QueryReply, ServiceError>> {
        let mut out: Vec<Option<Result<QueryReply, ServiceError>>> =
            items.iter().map(|_| None).collect();
        let mut programs: Vec<Option<Arc<TaskProgram>>> = Vec::with_capacity(items.len());
        for (i, (q, _)) in items.iter().enumerate() {
            match self.program(&q.app, q.n, q.bs) {
                Ok(p) => programs.push(Some(p)),
                Err(e) => {
                    out[i] = Some(Err(e));
                    programs.push(None);
                }
            }
        }
        let mut by_lane: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for (i, (q, _)) in items.iter().enumerate() {
            if programs[i].is_some() {
                by_lane.entry(self.lane_of(&q.app)).or_default().push(i);
            }
        }
        for (lane_idx, idxs) in by_lane {
            let mut lane = lock_unpoisoned(&self.lanes[lane_idx]);
            self.run_lane_items(&mut lane, items, &programs, &idxs, &mut out);
        }
        self.maybe_save();
        out.into_iter()
            .map(|r| r.expect("every item answered"))
            .collect()
    }

    /// Answer a `batch` envelope: parse-failed items answer their error
    /// in place, valid items run through the batch evaluator, and every
    /// item's response object is exactly what the standalone request
    /// line would have produced (same [`ok_obj`]/[`err_obj`] builders,
    /// same replies).
    fn run_batch(&self, batch: &[BatchItem]) -> QueryReply {
        let mut queries: Vec<(PointQuery, bool)> = Vec::new();
        let mut slots: Vec<Result<usize, ServiceError>> = Vec::with_capacity(batch.len());
        for item in batch {
            match &item.query {
                Ok(q) => {
                    slots.push(Ok(queries.len()));
                    queries.push((q.clone(), item.energy));
                }
                Err(e) => slots.push(Err(e.clone())),
            }
        }
        self.counters
            .batched
            .fetch_add(queries.len() as u64, Ordering::Relaxed);
        let replies = self.run_point_items(&queries);
        let mut objs: Vec<Value> = Vec::with_capacity(batch.len());
        let (mut l1, mut l2, mut evaluated, mut failed) = (0u64, 0u64, 0u64, 0u64);
        for (item, slot) in batch.iter().zip(&slots) {
            let req = if item.energy { "energy" } else { "estimate" };
            let obj = match slot {
                Ok(j) => match &replies[*j] {
                    Ok(reply) => {
                        l1 += reply.l1_hits;
                        l2 += reply.l2_hits;
                        evaluated += reply.evaluated;
                        ok_obj(&item.id, req, reply)
                    }
                    Err(e) => {
                        failed += 1;
                        err_obj(&item.id, e)
                    }
                },
                Err(e) => {
                    failed += 1;
                    err_obj(&item.id, e)
                }
            };
            objs.push(obj);
        }
        self.counters.errors.fetch_add(failed, Ordering::Relaxed);
        QueryReply {
            text: format!(
                "batch: {} items ({} evaluated, {} l2 hits, {} failed)\n",
                batch.len(),
                evaluated,
                l2,
                failed
            ),
            l1_hits: l1,
            l2_hits: l2,
            evaluated,
            extra: vec![
                ("items".into(), Value::Arr(objs)),
                ("items_total".into(), (batch.len() as u64).into()),
                ("items_failed".into(), failed.into()),
            ],
        }
    }

    fn run_query(&self, env: &Envelope) -> Result<QueryReply, ServiceError> {
        let map_err = |e: anyhow::Error| ServiceError::usage(format!("{e:#}"));
        match &env.kind {
            RequestKind::Estimate(q) | RequestKind::Energy(q) => {
                let energy = matches!(env.kind, RequestKind::Energy(_));
                let mut replies = self.run_point_items(&[(q.clone(), energy)]);
                replies.pop().expect("one item, one reply")
            }
            RequestKind::Batch(items) => Ok(self.run_batch(items)),
            RequestKind::Dse(q) => {
                let program = self.program(&q.app, q.n, q.bs)?;
                let workers = self.workers();
                let lane_idx = self.lane_of(&q.app);
                let reply = {
                    let mut lane = lock_unpoisoned(&self.lanes[lane_idx]);
                    // Sweeps mutate the memo throughout (bound seeding +
                    // recording), so they run under the write lock; lanes
                    // still overlap on their point-query evaluations.
                    let mut memo = write_unpoisoned(&self.memo);
                    dse_query(
                        &program,
                        &self.board,
                        &self.part,
                        q,
                        workers,
                        &mut memo,
                        lane.journal.as_mut(),
                    )
                    .map_err(map_err)?
                };
                self.bump_warmth(&reply);
                self.maybe_save();
                Ok(reply)
            }
            RequestKind::MemoStats => {
                let stats = read_unpoisoned(&self.memo).stats();
                let degraded = self.save_failed.load(Ordering::Relaxed);
                let saves = self.counters.saves.load(Ordering::Relaxed);
                let mut text = stats.render();
                text.push_str(&format!(
                    "service: {} requests, {} coalesced, {} batched, {} evaluated, \
                     {} errors, {} saves, {} lanes{}\n",
                    self.requests(),
                    self.coalesced(),
                    self.batched(),
                    self.evaluated(),
                    self.errors(),
                    saves,
                    self.lanes.len(),
                    if degraded { ", DEGRADED" } else { "" },
                ));
                let extra = crate::metrics::export::service_stats_fields(
                    &stats,
                    self.requests(),
                    self.coalesced(),
                    self.batched(),
                    self.evaluated(),
                    self.errors(),
                    saves,
                    self.lanes.len() as u64,
                    degraded,
                );
                Ok(QueryReply {
                    text,
                    l1_hits: self.counters.l1_hits.load(Ordering::Relaxed),
                    l2_hits: self.counters.l2_hits.load(Ordering::Relaxed),
                    evaluated: 0,
                    extra,
                })
            }
            RequestKind::MemoGc(spec) => {
                let (report, n_contexts, n_points, n_kernels) = {
                    let mut memo = write_unpoisoned(&self.memo);
                    let report = match spec.max_bytes {
                        Some(max) => memo.gc_bytes(max, spec.app_floor),
                        None => memo.gc(spec.keep_contexts, spec.keep_points, spec.keep_kernels),
                    };
                    (
                        report,
                        memo.n_contexts(),
                        memo.n_points(),
                        memo.n_kernel_entries(),
                    )
                };
                // Persist immediately: the WALs may reference evicted
                // contexts, so the post-gc truth must reach disk before
                // any replay could resurrect them.
                self.save_all();
                let text = format!(
                    "gc: evicted {} contexts ({} points) and {} kernel entries \
                     ({} contexts, {} points, {} kernel entries retained, all bit-exact)\n",
                    report.evicted_contexts,
                    report.evicted_points,
                    report.evicted_kernels,
                    n_contexts,
                    n_points,
                    n_kernels,
                );
                Ok(QueryReply {
                    text,
                    extra: vec![
                        (
                            "evicted_contexts".into(),
                            (report.evicted_contexts as u64).into(),
                        ),
                        (
                            "evicted_points".into(),
                            (report.evicted_points as u64).into(),
                        ),
                        (
                            "evicted_kernels".into(),
                            (report.evicted_kernels as u64).into(),
                        ),
                    ],
                    ..QueryReply::default()
                })
            }
            RequestKind::Ping => Ok(QueryReply {
                text: "pong\n".into(),
                ..QueryReply::default()
            }),
            RequestKind::Shutdown => unreachable!("shutdown handled in handle_line"),
        }
    }

    /// Run one coalescable query. The leader (first arrival for the key)
    /// evaluates under panic isolation and fans the result out; followers
    /// wait and clone it, so all coalesced responses are bitwise
    /// identical and exactly one evaluation happened.
    fn coalesced_query(&self, key: String, env: &Envelope) -> Result<QueryReply, ServiceError> {
        let cell = {
            let mut inflight = lock_unpoisoned(&self.inflight);
            match inflight.get(&key) {
                Some(cell) => {
                    self.counters.coalesced.fetch_add(1, Ordering::Relaxed);
                    let cell = Arc::clone(cell);
                    drop(inflight);
                    let mut slot = lock_unpoisoned(&cell.slot);
                    while slot.is_none() {
                        slot = cell
                            .done
                            .wait(slot)
                            .unwrap_or_else(|p| p.into_inner());
                    }
                    return slot.clone().expect("slot published before notify");
                }
                None => {
                    let cell = Arc::new(InFlight::new());
                    inflight.insert(key.clone(), Arc::clone(&cell));
                    cell
                }
            }
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| self.run_query(env)))
            .unwrap_or_else(|_| {
                Err(ServiceError::usage(
                    "evaluation panicked (see stderr); request dropped",
                ))
            });
        lock_unpoisoned(&self.inflight).remove(&key);
        *lock_unpoisoned(&cell.slot) = Some(result.clone());
        cell.done.notify_all();
        result
    }

    /// The window-batched point path (`--batch-window-ms > 0`): the first
    /// arrival of a lane becomes the window leader, sleeps out the
    /// accumulation window while later arrivals enqueue, then runs the
    /// whole window as one batch round and fans the per-request replies
    /// back out — each byte-identical to handling the same arrivals
    /// sequentially. Windowed queries skip the coalescing table: within a
    /// batch, a duplicate item is a level-2 hit of its predecessor, which
    /// is the sequential answer.
    fn windowed_point(&self, q: &PointQuery, energy: bool) -> Result<QueryReply, ServiceError> {
        let lane_idx = self.lane_of(&q.app);
        let cell = Arc::new(InFlight::new());
        let leader = {
            let mut w = lock_unpoisoned(&self.windows[lane_idx]);
            w.pending.push(PendingPoint {
                query: q.clone(),
                energy,
                cell: Arc::clone(&cell),
            });
            !std::mem::replace(&mut w.collecting, true)
        };
        if leader {
            std::thread::sleep(std::time::Duration::from_millis(self.cfg.batch_window_ms));
            let pending = {
                let mut w = lock_unpoisoned(&self.windows[lane_idx]);
                w.collecting = false;
                std::mem::take(&mut w.pending)
            };
            let items: Vec<(PointQuery, bool)> = pending
                .iter()
                .map(|p| (p.query.clone(), p.energy))
                .collect();
            self.counters
                .batched
                .fetch_add(items.len() as u64, Ordering::Relaxed);
            let replies =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    self.run_point_items(&items)
                }))
                .unwrap_or_else(|_| {
                    items
                        .iter()
                        .map(|_| {
                            Err(ServiceError::usage(
                                "evaluation panicked (see stderr); request dropped",
                            ))
                        })
                        .collect()
                });
            for (p, reply) in pending.iter().zip(replies) {
                *lock_unpoisoned(&p.cell.slot) = Some(reply);
                p.cell.done.notify_all();
            }
        }
        let mut slot = lock_unpoisoned(&cell.slot);
        loop {
            match slot.take() {
                Some(res) => return res,
                None => slot = cell.done.wait(slot).unwrap_or_else(|p| p.into_inner()),
            }
        }
    }

    /// Process one NDJSON line. Returns the response line (None for
    /// blank input) and whether the daemon should shut down.
    pub fn handle_line(&self, line: &str) -> (Option<String>, bool) {
        let line = line.trim();
        if line.is_empty() {
            return (None, false);
        }
        self.counters.requests.fetch_add(1, Ordering::Relaxed);
        let env = match parse_request(line) {
            Ok(env) => env,
            Err((id, err)) => {
                self.counters.errors.fetch_add(1, Ordering::Relaxed);
                return (Some(err_line(&id, &err)), false);
            }
        };
        if matches!(env.kind, RequestKind::Shutdown) {
            let code = self.finalize();
            let reply = QueryReply {
                text: if code == 0 {
                    "shutdown: memo saved\n".into()
                } else {
                    "shutdown: DEGRADED (memo save failed; WAL retained)\n".into()
                },
                extra: vec![("exit_code".into(), Value::Int(code as i64))],
                ..QueryReply::default()
            };
            return (Some(ok_line(&env.id, env.req_name(), &reply)), true);
        }
        let result = match &env.kind {
            RequestKind::Estimate(q) | RequestKind::Energy(q)
                if self.cfg.batch_window_ms > 0 =>
            {
                self.windowed_point(q, matches!(env.kind, RequestKind::Energy(_)))
            }
            _ => match env.coalesce_key() {
                Some(key) => self.coalesced_query(key, &env),
                None => self.run_query(&env),
            },
        };
        match result {
            Ok(reply) => (Some(ok_line(&env.id, env.req_name(), &reply)), false),
            Err(err) => {
                self.counters.errors.fetch_add(1, Ordering::Relaxed);
                (Some(err_line(&env.id, &err)), false)
            }
        }
    }

    /// Final save + exit code; idempotent (a TCP shutdown racing stdin
    /// EOF performs one save). `0` clean, `1` when any save failed.
    pub fn finalize(&self) -> i32 {
        let mut code_slot = lock_unpoisoned(&self.exit_code);
        if let Some(code) = *code_slot {
            return code;
        }
        self.shutdown.store(true, Ordering::SeqCst);
        self.save_all();
        let code = i32::from(self.save_failed.load(Ordering::Relaxed));
        *code_slot = Some(code);
        code
    }

    /// Whether a shutdown request has been processed.
    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }
}

/// One NDJSON connection loop over any buffered reader/writer pair.
/// Returns `true` when the peer asked for shutdown.
fn serve_connection<R: BufRead, W: Write>(svc: &Service, reader: R, mut writer: W) -> bool {
    for line in reader.lines() {
        let Ok(line) = line else { break };
        let (response, quit) = svc.handle_line(&line);
        if let Some(r) = response {
            if writeln!(writer, "{r}").and_then(|_| writer.flush()).is_err() {
                break;
            }
        }
        if quit {
            return true;
        }
        if svc.is_shutdown() {
            break;
        }
    }
    false
}

/// Accept loop of the TCP transport: non-blocking accept polled against
/// the shutdown flag, one thread per connection. A `shutdown` request on
/// a TCP connection finalizes and exits the whole process (stdin cannot
/// be unblocked portably).
fn serve_tcp(svc: Arc<Service>, listener: std::net::TcpListener) {
    listener
        .set_nonblocking(true)
        .expect("set_nonblocking on listener");
    loop {
        if svc.is_shutdown() {
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                let svc = Arc::clone(&svc);
                std::thread::spawn(move || {
                    let reader = std::io::BufReader::new(match stream.try_clone() {
                        Ok(s) => s,
                        Err(_) => return,
                    });
                    if serve_connection(&svc, reader, &stream) {
                        let code = svc.finalize();
                        std::process::exit(code);
                    }
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(25));
            }
            Err(_) => return,
        }
    }
}

/// Run the daemon to completion on the current thread: bind the optional
/// TCP listener, then serve stdin/stdout until a `shutdown` request or
/// EOF. Returns the process exit code.
pub fn serve(board: BoardConfig, cfg: ServeConfig) -> anyhow::Result<i32> {
    run(Service::new(board, cfg)?)
}

/// [`serve`] with a prebuilt service — lets callers distinguish
/// construction failures (memo load) from runtime ones (bind).
pub fn run(svc: Service) -> anyhow::Result<i32> {
    let listen = svc.cfg.listen.clone();
    if svc.lanes() > 1 || svc.cfg.batch_window_ms > 0 {
        eprintln!(
            "serve: {} lanes, batch window {} ms",
            svc.lanes(),
            svc.cfg.batch_window_ms
        );
    }
    let svc = Arc::new(svc);
    if let Some(addr) = listen {
        let listener = std::net::TcpListener::bind(&addr)
            .map_err(|e| anyhow::anyhow!("serve: cannot listen on {addr}: {e}"))?;
        // Tests and CI parse this line to discover an OS-assigned port
        // (always bind port 0 in scripts — fixed ports collide).
        eprintln!("serve: listening on {}", listener.local_addr()?);
        let svc = Arc::clone(&svc);
        std::thread::spawn(move || serve_tcp(svc, listener));
    }
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    if serve_connection(&svc, stdin.lock(), stdout.lock()) {
        return Ok(svc.finalize());
    }
    // stdin closed without a shutdown request: if a TCP shutdown already
    // ran, report its code; otherwise treat EOF as a graceful shutdown.
    Ok(svc.finalize())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn service() -> Service {
        Service::new(BoardConfig::zynq706(), ServeConfig::default()).unwrap()
    }

    fn service_with(lanes: usize, batch_window_ms: u64) -> Service {
        Service::new(
            BoardConfig::zynq706(),
            ServeConfig {
                lanes,
                batch_window_ms,
                ..ServeConfig::default()
            },
        )
        .unwrap()
    }

    fn get_u64(v: &crate::util::json::Value, key: &str) -> u64 {
        v.get(key).and_then(|x| x.as_u64()).unwrap()
    }

    #[test]
    fn estimate_then_repeat_hits_the_memo_with_identical_response() {
        let svc = service();
        let req = r#"{"id":1,"req":"estimate","app":"matmul","n":256,"accel":["mxm64:U32"]}"#;
        let (first, quit) = svc.handle_line(req);
        assert!(!quit);
        let first = first.unwrap();
        let (second, _) = svc.handle_line(req);
        let second = second.unwrap();
        assert_eq!(first, second, "hit must be bitwise identical to the evaluation");
        let v = parse(&second).unwrap();
        assert_eq!(get_u64(&v, "evaluated"), 0);
        assert_eq!(get_u64(&v, "l2_hits"), 1);
        assert_eq!(svc.evaluated(), 1, "one evaluation total across both");
    }

    #[test]
    fn malformed_lines_answer_with_the_cli_error_taxonomy_and_keep_serving() {
        let svc = service();
        let (bad, quit) = svc.handle_line("this is not json");
        assert!(!quit);
        let bad = parse(&bad.unwrap()).unwrap();
        assert_eq!(bad.get("ok").and_then(|v| v.as_bool()), Some(false));
        assert_eq!(get_u64(&bad, "code"), 1);
        let (unknown, _) = svc.handle_line(r#"{"id":7,"req":"frobnicate"}"#);
        let unknown = parse(&unknown.unwrap()).unwrap();
        assert_eq!(get_u64(&unknown, "code"), 2);
        assert_eq!(
            unknown.get("id").and_then(|v| v.as_i64()),
            Some(7),
            "errors still correlate by id"
        );
        let (ping, _) = svc.handle_line(r#"{"req":"ping"}"#);
        let ping = parse(&ping.unwrap()).unwrap();
        assert_eq!(ping.get("ok").and_then(|v| v.as_bool()), Some(true));
        assert_eq!(svc.errors(), 2);
    }

    #[test]
    fn stats_reports_cumulative_counters_and_gc_runs_in_place() {
        let svc = service();
        svc.handle_line(r#"{"req":"estimate","app":"matmul","n":128,"accel":["mxm64:U8"]}"#);
        svc.handle_line(r#"{"req":"estimate","app":"matmul","n":128,"accel":["mxm64:U8"]}"#);
        let (stats, _) = svc.handle_line(r#"{"req":"memo","action":"stats"}"#);
        let stats = parse(&stats.unwrap()).unwrap();
        assert_eq!(get_u64(&stats, "contexts"), 1);
        assert_eq!(get_u64(&stats, "total_evaluated"), 1);
        assert_eq!(get_u64(&stats, "requests"), 3);
        assert_eq!(get_u64(&stats, "lanes"), 1);
        let (gc, _) = svc.handle_line(r#"{"req":"memo","action":"gc","max_bytes":0,"app_floor":1}"#);
        let gc = parse(&gc.unwrap()).unwrap();
        assert_eq!(
            get_u64(&gc, "evicted_contexts"),
            0,
            "the per-app floor protects the only context even under a zero budget"
        );
    }

    #[test]
    fn shutdown_line_finalizes_and_requests_exit() {
        let svc = service();
        let (resp, quit) = svc.handle_line(r#"{"id":9,"req":"shutdown"}"#);
        assert!(quit);
        let v = parse(&resp.unwrap()).unwrap();
        assert_eq!(v.get("ok").and_then(|x| x.as_bool()), Some(true));
        assert_eq!(v.get("exit_code").and_then(|x| x.as_i64()), Some(0));
        assert!(svc.is_shutdown());
        assert_eq!(svc.finalize(), 0, "finalize is idempotent");
    }

    #[test]
    fn batch_envelope_items_equal_the_standalone_response_lines() {
        // Reference: two standalone requests on a fresh service.
        let seq = service();
        let est = r#"{"id":"a","req":"estimate","app":"matmul","n":256,"accel":["mxm64:U32"]}"#;
        let en = r#"{"id":"b","req":"energy","app":"matmul","n":256,"accel":["mxm64:U32"]}"#;
        let (est_line, _) = seq.handle_line(est);
        let (en_line, _) = seq.handle_line(en);
        // Batch: the same two queries in one envelope on a fresh service.
        let svc = service_with(4, 0);
        let (resp, _) = svc.handle_line(
            r#"{"id":8,"req":"batch","items":[
                {"id":"a","req":"estimate","app":"matmul","n":256,"accel":["mxm64:U32"]},
                {"id":"b","req":"energy","app":"matmul","n":256,"accel":["mxm64:U32"]},
                {"id":"c","req":"estimate"}]}"#,
        );
        let v = parse(&resp.unwrap()).unwrap();
        assert_eq!(v.get("ok").and_then(|x| x.as_bool()), Some(true));
        assert_eq!(get_u64(&v, "evaluated"), 1, "energy reuses the estimate's point");
        assert_eq!(get_u64(&v, "items_failed"), 1);
        let Some(Value::Arr(items)) = v.get("items") else {
            panic!("batch response carries items");
        };
        assert_eq!(items.len(), 3);
        assert_eq!(items[0].to_json(), parse(&est_line.unwrap()).unwrap().to_json());
        assert_eq!(items[1].to_json(), parse(&en_line.unwrap()).unwrap().to_json());
        assert_eq!(items[2].get("ok").and_then(|x| x.as_bool()), Some(false));
        assert_eq!(svc.batched(), 2, "only valid items enter the batch round");
        assert_eq!(svc.errors(), 1, "the failed item counts as an error");
    }

    #[test]
    fn multi_lane_service_shards_apps_and_answers_like_single_lane() {
        let multi = service_with(4, 0);
        let single = service();
        assert_eq!(multi.lanes(), 4);
        let reqs = [
            r#"{"req":"estimate","app":"matmul","n":256,"accel":["mxm64:U32"]}"#,
            r#"{"req":"estimate","app":"lu","n":256,"accel":["trsm_row:U16"]}"#,
            r#"{"req":"estimate","app":"matmul","n":256,"accel":["mxm64:U32"]}"#,
        ];
        for req in reqs {
            let (a, _) = multi.handle_line(req);
            let (b, _) = single.handle_line(req);
            assert_eq!(a, b, "lane count must never change a response byte");
        }
        assert_eq!(multi.evaluated(), single.evaluated());
    }

    #[test]
    fn windowed_point_queries_batch_and_answer_identically() {
        let windowed = service_with(2, 5);
        let plain = service();
        let req = r#"{"id":1,"req":"estimate","app":"matmul","n":256,"accel":["mxm64:U32"]}"#;
        let (a, _) = windowed.handle_line(req);
        let (b, _) = plain.handle_line(req);
        assert_eq!(a, b, "the window changes latency, never bytes");
        assert_eq!(windowed.batched(), 1);
    }
}
