//! Deterministic fault injection for the crash-safety tests and the CLI.
//!
//! A *faultpoint* is a named site in the code (the registered sites:
//! `memo.save`, `memo.load`, `wal.append`, `wal.replay`, `eval.point`,
//! `delta.plan`, `board.toml`, `sweep.round`, and the service daemon's
//! overload sites `conn.read`, `conn.write`, `queue.admit`,
//! `save.breaker`) that
//! normally does nothing and costs one
//! relaxed atomic load. Arming a spec — from a test, `--faults` on the
//! CLI, or the `ZYNQ_FAULTS` environment variable — makes the matching
//! site fail deterministically: by hit count for serial sites, or by a
//! site-specific *tag* for parallel sites (a tag is derived from the work
//! item, e.g. the FNV hash of a co-design key, so which points fail never
//! depends on worker scheduling). There is deliberately no randomness:
//! every fault a test provokes is reproducible bit-for-bit.
//!
//! Spec grammar (comma-separated list):
//!
//! ```text
//! site[@N][#HEXTAG][!error|!panic|!abort]
//! ```
//!
//! * `site` — the faultpoint name (exact match).
//! * `@N` — fire on the N-th matching hit only (default: the first).
//!   Counting is per spec, under a lock; meaningful for sites hit from a
//!   single thread (saves, WAL appends, round commits).
//! * `#HEXTAG` — fire on every hit whose tag equals the hex value;
//!   schedule-independent, the right selector for parallel sites.
//! * `!error` (default) — the site returns an error; `!panic` — the site
//!   panics (exercises the poison-isolation path); `!abort` — the process
//!   aborts (exercises kill -9 recovery from a child process).
//!
//! The registered sites are listed in ARCHITECTURE.md ("Failure model &
//! recovery").

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use super::fnv::Fnv;

/// How an armed faultpoint manifests when it fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultMode {
    /// The site returns `Err` (default) — exercises error propagation.
    Error,
    /// The site panics — exercises worker poison isolation.
    Panic,
    /// The process aborts — a stand-in for kill -9 in subprocess tests.
    Abort,
}

#[derive(Debug)]
struct FaultSpec {
    id: u64,
    site: String,
    /// Fire on the n-th matching hit (1-based); `None` = first.
    nth: Option<u64>,
    /// Fire only on hits carrying this tag; tagged specs fire on *every*
    /// matching hit unless `nth` narrows them.
    tag: Option<u64>,
    mode: FaultMode,
    hits: u64,
    spent: bool,
}

/// Fast path: a single relaxed load when nothing is armed.
static ANY_ARMED: AtomicBool = AtomicBool::new(false);
static NEXT_ID: AtomicU64 = AtomicU64::new(1);
static SPECS: Mutex<Vec<FaultSpec>> = Mutex::new(Vec::new());

/// RAII guard for faults armed by [`arm`]; dropping it disarms exactly the
/// specs it armed (tests stack guards safely).
pub struct Armed {
    ids: Vec<u64>,
}

impl Drop for Armed {
    fn drop(&mut self) {
        let mut specs = SPECS.lock().unwrap();
        specs.retain(|s| !self.ids.contains(&s.id));
        ANY_ARMED.store(!specs.is_empty(), Ordering::Relaxed);
    }
}

fn parse_one(spec: &str) -> anyhow::Result<(String, Option<u64>, Option<u64>, FaultMode)> {
    let mut rest = spec.trim();
    anyhow::ensure!(!rest.is_empty(), "empty fault spec");
    let mut mode = FaultMode::Error;
    if let Some((head, m)) = rest.rsplit_once('!') {
        mode = match m {
            "error" => FaultMode::Error,
            "panic" => FaultMode::Panic,
            "abort" => FaultMode::Abort,
            other => {
                anyhow::bail!("fault spec '{spec}': unknown mode '!{other}' (error|panic|abort)")
            }
        };
        rest = head;
    }
    let mut tag = None;
    if let Some((head, t)) = rest.rsplit_once('#') {
        let v = u64::from_str_radix(t, 16)
            .map_err(|_| anyhow::anyhow!("fault spec '{spec}': bad hex tag '#{t}'"))?;
        tag = Some(v);
        rest = head;
    }
    let mut nth = None;
    if let Some((head, n)) = rest.rsplit_once('@') {
        let v: u64 = n
            .parse()
            .map_err(|_| anyhow::anyhow!("fault spec '{spec}': bad hit count '@{n}'"))?;
        anyhow::ensure!(v >= 1, "fault spec '{spec}': hit count must be >= 1");
        nth = Some(v);
        rest = head;
    }
    anyhow::ensure!(!rest.is_empty(), "fault spec '{spec}': missing site name");
    Ok((rest.to_string(), nth, tag, mode))
}

/// Arm one or more comma-separated fault specs; returns a guard that
/// disarms them on drop.
pub fn arm(specs: &str) -> anyhow::Result<Armed> {
    let mut parsed = Vec::new();
    for one in specs.split(',').filter(|s| !s.trim().is_empty()) {
        parsed.push(parse_one(one)?);
    }
    anyhow::ensure!(!parsed.is_empty(), "no fault specs in '{specs}'");
    let mut ids = Vec::new();
    let mut table = SPECS.lock().unwrap();
    for (site, nth, tag, mode) in parsed {
        let id = NEXT_ID.fetch_add(1, Ordering::Relaxed);
        ids.push(id);
        table.push(FaultSpec {
            id,
            site,
            nth,
            tag,
            mode,
            hits: 0,
            spent: false,
        });
    }
    ANY_ARMED.store(true, Ordering::Relaxed);
    Ok(Armed { ids })
}

/// Arm from the `ZYNQ_FAULTS` environment variable, if set. Returns the
/// guard when something was armed (callers keep it alive for the process);
/// `Ok(None)` when the variable is unset or empty.
pub fn arm_from_env() -> anyhow::Result<Option<Armed>> {
    match std::env::var("ZYNQ_FAULTS") {
        Ok(v) if !v.trim().is_empty() => arm(&v).map(Some),
        _ => Ok(None),
    }
}

/// Disarm every registered fault (test hygiene).
pub fn disarm_all() {
    let mut specs = SPECS.lock().unwrap();
    specs.clear();
    ANY_ARMED.store(false, Ordering::Relaxed);
}

/// Whether any fault spec is currently armed (one relaxed load) — lets
/// hot paths skip computing a tag when nothing can fire.
pub fn armed() -> bool {
    ANY_ARMED.load(Ordering::Relaxed)
}

/// The canonical tag of a string work-item key: its FNV-1a 64 hash (print
/// it with `{:x}` to build a `site#HEXTAG` spec).
pub fn str_tag(s: &str) -> u64 {
    let mut h = Fnv::new();
    h.str(s);
    h.finish()
}

fn fire(site: &str, tag: Option<u64>) -> Option<FaultMode> {
    let mut specs = SPECS.lock().unwrap();
    for s in specs.iter_mut() {
        if s.site != site {
            continue;
        }
        match (s.tag, tag) {
            (Some(want), Some(got)) if want != got => continue,
            (Some(_), None) => continue,
            _ => {}
        }
        s.hits += 1;
        let due = match s.nth {
            Some(n) => s.hits == n,
            // Untagged specs default to one-shot (the first hit); tagged
            // specs fire on every matching hit — the tag already selects
            // a deterministic subset.
            None => s.tag.is_some() || !s.spent,
        };
        if due {
            s.spent = true;
            return Some(s.mode);
        }
    }
    None
}

/// A faultpoint without a tag. Returns `Err` when an armed `!error` spec
/// fires; panics or aborts for the other modes.
pub fn hit(site: &str) -> anyhow::Result<()> {
    if !ANY_ARMED.load(Ordering::Relaxed) {
        return Ok(());
    }
    hit_tagged_inner(site, None)
}

/// A faultpoint carrying a work-item tag (see [`str_tag`]).
pub fn hit_tagged(site: &str, tag: u64) -> anyhow::Result<()> {
    if !ANY_ARMED.load(Ordering::Relaxed) {
        return Ok(());
    }
    hit_tagged_inner(site, Some(tag))
}

fn hit_tagged_inner(site: &str, tag: Option<u64>) -> anyhow::Result<()> {
    match fire(site, tag) {
        None => Ok(()),
        Some(FaultMode::Error) => Err(anyhow::anyhow!("injected fault at '{site}'")),
        Some(FaultMode::Panic) => panic!("injected fault (panic) at '{site}'"),
        Some(FaultMode::Abort) => {
            eprintln!("injected fault (abort) at '{site}'");
            std::process::abort();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Faultpoint state is process-global; serialize the tests that arm it.
    // Sites here use fictional `t.*` names only — arming a *real* site
    // name (wal.append, sweep.round, ...) would fire inside unrelated lib
    // tests running on other threads. Real-site arming lives in the
    // `crash_recovery` integration suite (its own process).
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn unarmed_sites_are_free() {
        let _g = TEST_LOCK.lock().unwrap();
        disarm_all();
        for _ in 0..1000 {
            assert!(hit("t.serial").is_ok());
            assert!(hit_tagged("t.tagged", 42).is_ok());
        }
    }

    #[test]
    fn untagged_spec_fires_once_on_first_hit() {
        let _g = TEST_LOCK.lock().unwrap();
        disarm_all();
        let guard = arm("t.append").unwrap();
        assert!(hit("t.append").is_err());
        assert!(hit("t.append").is_ok(), "one-shot spec must stay spent");
        assert!(hit("t.load").is_ok(), "other sites unaffected");
        drop(guard);
        assert!(hit("t.append").is_ok(), "drop disarms");
    }

    #[test]
    fn nth_spec_counts_hits() {
        let _g = TEST_LOCK.lock().unwrap();
        disarm_all();
        let _guard = arm("t.round@3").unwrap();
        assert!(hit("t.round").is_ok());
        assert!(hit("t.round").is_ok());
        assert!(hit("t.round").is_err());
        assert!(hit("t.round").is_ok());
    }

    #[test]
    fn tagged_spec_selects_by_tag_every_time() {
        let _g = TEST_LOCK.lock().unwrap();
        disarm_all();
        let tag = str_tag("1xmxm64:U32");
        let _guard = arm(&format!("t.point#{tag:x}")).unwrap();
        assert!(hit_tagged("t.point", tag).is_err());
        assert!(hit_tagged("t.point", tag).is_err(), "tagged specs re-fire");
        assert!(hit_tagged("t.point", tag ^ 1).is_ok());
        assert!(hit("t.point").is_ok(), "untagged hit never matches a tagged spec");
    }

    #[test]
    fn spec_parser_accepts_the_grammar_and_rejects_garbage() {
        let _g = TEST_LOCK.lock().unwrap();
        disarm_all();
        for ok in [
            "t.save.temp",
            "t.save.rename!panic",
            "t.replay@2",
            "t.point#abc123!panic",
            "t.a,t.b@2,t.c!abort",
        ] {
            assert!(arm(ok).is_ok(), "{ok}");
            disarm_all();
        }
        for bad in ["", " , ", "site!frobnicate", "site@zero", "site@0", "site#xyz", "@1"] {
            assert!(arm(bad).is_err(), "{bad}");
        }
        disarm_all();
    }

    #[test]
    fn guards_stack_independently() {
        let _g = TEST_LOCK.lock().unwrap();
        disarm_all();
        let g1 = arm("t.a.site").unwrap();
        let g2 = arm("t.b.site").unwrap();
        drop(g1);
        assert!(hit("t.b.site").is_err(), "g2 outlives g1");
        drop(g2);
        assert!(hit("t.b.site").is_ok());
    }
}
