//! Minimal JSON substrate: a `Value` tree, a recursive-descent parser and a
//! writer. Used for the task-trace interchange format (paper §IV) and for
//! machine-readable experiment reports.
//!
//! Scope: full JSON grammar (RFC 8259) minus `\u` surrogate-pair pedantry
//! beyond the BMP; numbers are parsed as f64 with an i64 fast path so 64-bit
//! addresses survive a round-trip.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept in a `BTreeMap` so output is
/// deterministic (stable diffs in golden tests).
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// JSON `null`.
    Null,
    /// Boolean.
    Bool(bool),
    /// Integer fast path — preserves u64/i64 exactly (addresses, cycle
    /// counts). Writers emit it without a decimal point.
    Int(i64),
    /// Floating-point number.
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Value>),
    /// Object (keys sorted, deterministic output).
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// The value as `i64`, if it is an integer.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            Value::Num(f) if f.fract() == 0.0 && f.abs() < 9e15 => Some(*f as i64),
            _ => None,
        }
    }

    /// The value as `u64`, if it is a non-negative integer.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_i64().and_then(|i| u64::try_from(i).ok())
    }

    /// The value as `f64` (integers convert).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Num(f) => Some(*f),
            _ => None,
        }
    }

    /// The value as a string slice, if it is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The value as a bool, if it is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The value as an array slice, if it is an array.
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The value as an object map, if it is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field access: `v.get("deps")`.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Serialize to a compact JSON string.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(true) => out.push_str("true"),
            Value::Bool(false) => out.push_str("false"),
            Value::Int(i) => out.push_str(&i.to_string()),
            Value::Num(f) => {
                if f.is_finite() {
                    out.push_str(&format!("{f}"));
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Build an array value.
pub fn arr(items: Vec<Value>) -> Value {
    Value::Arr(items)
}

impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl From<i64> for Value {
    fn from(i: i64) -> Self {
        Value::Int(i)
    }
}
impl From<u64> for Value {
    fn from(i: u64) -> Self {
        Value::Int(i as i64)
    }
}
impl From<u32> for Value {
    fn from(i: u32) -> Self {
        Value::Int(i as i64)
    }
}
impl From<usize> for Value {
    fn from(i: usize) -> Self {
        Value::Int(i as i64)
    }
}
impl From<f64> for Value {
    fn from(f: f64) -> Self {
        Value::Num(f)
    }
}
impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
/// Parse failure: byte position and message.
pub struct ParseError {
    /// Byte offset the parser stopped at.
    pub pos: usize,
    /// Human-readable description.
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

/// Parse a JSON document (must consume the full input up to trailing
/// whitespace).
pub fn parse(s: &str) -> Result<Value, ParseError> {
    let mut p = Parser {
        b: s.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing garbage"));
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ') | Some(b'\t') | Some(b'\n') | Some(b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Value::Null),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'"') => self.string().map(Value::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        if self.pos + 4 > self.b.len() {
                            return Err(self.err("truncated \\u escape"));
                        }
                        let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                            .map_err(|_| self.err("bad \\u escape"))?;
                        let cp = u32::from_str_radix(hex, 16)
                            .map_err(|_| self.err("bad \\u escape"))?;
                        self.pos += 4;
                        s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Multi-byte UTF-8: count continuation bytes.
                    let extra = if c >= 0xF0 {
                        3
                    } else if c >= 0xE0 {
                        2
                    } else {
                        1
                    };
                    let start = self.pos - 1;
                    self.pos += extra;
                    if self.pos > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        if !is_float {
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Value::Int(i));
            }
        }
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-7", "3.5", "\"hi\""] {
            let v = parse(s).unwrap();
            assert_eq!(parse(&v.to_json()).unwrap(), v);
        }
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn big_ints_survive() {
        let addr = 0x7fff_ffff_f000u64;
        let v = obj(vec![("addr", addr.into())]);
        let back = parse(&v.to_json()).unwrap();
        assert_eq!(back.get("addr").unwrap().as_u64().unwrap(), addr);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("\"abc").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""éA""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "éA");
    }

    #[test]
    fn utf8_passthrough() {
        let v = parse("\"día ☀\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "día ☀");
        assert_eq!(parse(&Value::from("día ☀").to_json()).unwrap(), v);
    }

    #[test]
    fn deterministic_object_order() {
        let v = parse(r#"{"z":1,"a":2}"#).unwrap();
        assert_eq!(v.to_json(), r#"{"a":2,"z":1}"#);
    }

    #[test]
    fn float_roundtrip() {
        let v = parse("1e3").unwrap();
        assert_eq!(v.as_f64().unwrap(), 1000.0);
        let v = parse("-2.5e-2").unwrap();
        assert!((v.as_f64().unwrap() + 0.025).abs() < 1e-12);
    }
}
