//! Fig. 7 regeneration: Paraver traces of the four matmul configurations
//! the paper visualizes (1acc 128, 2acc 64, 2acc 64 + SMP, 1acc 128 + SMP)
//! at the same time scale, plus writer throughput.

use zynq_estimator::apps::matmul;
use zynq_estimator::config::BoardConfig;
use zynq_estimator::experiments;
use zynq_estimator::sim::estimate;
use zynq_estimator::trace::paraver;
use zynq_estimator::util::bench::{bench, black_box};

fn main() {
    let board = BoardConfig::zynq706();
    let out = std::path::PathBuf::from("out/paraver");
    let stems = experiments::fig7(512, &board, &out).unwrap();
    println!("=== Fig. 7: Paraver bundles (same time axis; load in wxparaver) ===");
    for s in &stems {
        let prv = std::fs::read_to_string(s.with_extension("prv")).unwrap();
        let header = prv.lines().next().unwrap().to_string();
        let dur_ns: u64 = header
            .split_once("):")
            .unwrap()
            .1
            .split(':')
            .next()
            .unwrap()
            .parse()
            .unwrap();
        println!(
            "  {:28} {:>10.1} ms  {:>7} records",
            s.file_name().unwrap().to_string_lossy(),
            dur_ns as f64 / 1e6,
            prv.lines().count() - 1
        );
    }
    println!("(paper reading: +smp traces show SMP bars loaded with slow mxmBlock tasks\n while the accelerator rows go idle — the load-imbalance story)\n");

    // Writer throughput.
    let (cd, app) = matmul::fig5_cases(512).into_iter().nth(1).unwrap(); // 2acc 64
    let program = app.build_program(&board);
    let res = estimate(&program, &cd, &board).unwrap();
    bench("paraver::to_prv (2acc 64, 512 tasks)", 3, 30, || {
        black_box(paraver::to_prv(&program, &board, &res));
    });
}
