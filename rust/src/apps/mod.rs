//! Application layer — the OmpSs-annotated programs of the paper.
//!
//! Each app builds a [`TaskProgram`]: the kernel declarations (the
//! `#pragma omp target device(...)` / `#pragma omp task in/out/inout`
//! annotations) plus the dynamic task trace the instrumented sequential
//! execution would record. Address assignment mirrors a real heap layout so
//! the run-time dependence tracker sees exactly the pattern Nanos++ would.
//!
//! * [`matmul`] — tiled matrix multiply (paper Fig. 1), BS ∈ {64, 128}.
//! * [`cholesky`] — tiled left-looking Cholesky (paper Fig. 4), 4 kernels.
//! * [`lu`] — tiled LU decomposition (extension app, 4 kernels).
//! * [`stencil`] — blocked Jacobi stencil (extra domain app exercising a
//!   halo-exchange dependence pattern the paper's intro motivates).

pub mod cholesky;
pub mod lu;
pub mod matmul;
pub mod stencil;

use crate::config::BoardConfig;
use crate::coordinator::task::{KernelProfile, TaskProgram};

/// The canonical benchmark-suite application list, in sweep order — the
/// one definition behind `dse --suite`, `dse --boards --suite` and the
/// suite experiment harness.
pub const SUITE_APPS: [&str; 4] = ["matmul", "cholesky", "lu", "stencil"];

/// Build an application's [`TaskProgram`] by name — the one shared
/// resolver behind the CLI (`--app`), the experiment harnesses and the
/// cross-board sweeps, so the app-name → constructor mapping (including
/// the stencil's halo depth) lives in exactly one place.
pub fn build_app_program(
    app: &str,
    n: u64,
    bs: u64,
    board: &BoardConfig,
) -> anyhow::Result<TaskProgram> {
    Ok(match app {
        "matmul" => matmul::Matmul::new(n, bs).build_program(board),
        "cholesky" => cholesky::Cholesky::new(n, bs).build_program(board),
        "lu" => lu::Lu::new(n, bs).build_program(board),
        "stencil" => stencil::Stencil::new(n, bs, 4).build_program(board),
        other => anyhow::bail!("unknown app '{other}' (matmul|cholesky|lu|stencil)"),
    })
}

/// Model of the instrumented sequential execution's per-task ARM cycle
/// count — the stand-in for the gettimeofday instrumentation of §V.
/// `flops / flops_per_cycle`, de-rated for double precision and for
/// division/sqrt-heavy kernels, matching how the A9 VFP behaves on -O3
/// compiled loops.
pub fn smp_cycles_model(profile: &KernelProfile, board: &BoardConfig) -> u64 {
    let mut cycles = profile.flops as f64 / board.smp_flops_per_cycle;
    if profile.dtype_bytes >= 8 {
        cycles *= board.smp_dp_penalty;
    }
    if profile.divsqrt {
        cycles *= board.smp_divsqrt_penalty;
    }
    // Capacity misses: working sets beyond the 32 KiB L1D pay an extra
    // factor per doubling (L2/TLB pressure). This is why an SMP 128-block
    // mxm is more than 8x an SMP 64-block mxm on the A9 — and why the
    // paper's slowest configuration is "1acc 128 + smp".
    let ws_kb = (profile.in_bytes + profile.out_bytes) as f64 / 1024.0;
    if ws_kb > board.smp_l1_kb {
        cycles *= 1.0 + board.smp_cache_alpha * (ws_kb / board.smp_l1_kb).log2();
    }
    cycles.round() as u64
}

/// Named co-design set for an app's paper experiment (one figure).
pub struct ExperimentSet {
    /// Application name.
    pub app: String,
    /// The co-designs the figure compares.
    pub codesigns: Vec<crate::config::CoDesign>,
    /// Name of the configuration the paper normalizes against (slowest).
    pub baseline: String,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smp_cycles_penalties_stack() {
        let b = BoardConfig::zynq706();
        let base = KernelProfile {
            flops: 1_000_000,
            inner_trip: 1,
            in_bytes: 1,
            out_bytes: 1,
            dtype_bytes: 4,
            divsqrt: false,
        };
        let c0 = smp_cycles_model(&base, &b);
        assert_eq!(c0, 2_000_000); // 0.5 flops/cycle

        let dp = KernelProfile {
            dtype_bytes: 8,
            ..base.clone()
        };
        assert_eq!(smp_cycles_model(&dp, &b), 3_200_000);

        let hard = KernelProfile {
            dtype_bytes: 8,
            divsqrt: true,
            ..base
        };
        assert_eq!(smp_cycles_model(&hard, &b), 7_040_000);
    }
}
