//! Cross-board sweep guarantees: the board-axis sweep is bit-identical for
//! any worker count in all three modes, the pruned modes keep their
//! losslessness contracts (per-board fronts for `explore_pruned`, merged
//! fronts for `explore_pruned_global`, property-tested over randomized
//! small spaces), and the reimplemented `experiments::cross_board_matmul`
//! reproduces the pre-refactor fixed-set decision rows bit for bit.

use zynq_estimator::apps::{cholesky::Cholesky, matmul, matmul::Matmul};
use zynq_estimator::board::BoardSpace;
use zynq_estimator::config::{BoardConfig, CoDesign};
use zynq_estimator::coordinator::sched::Policy;
use zynq_estimator::coordinator::task::TaskProgram;
use zynq_estimator::dse::{
    pareto_front_coords, CrossBoardResult, CrossBoardSweep, DseSpace, KernelSpace, Objective,
};
use zynq_estimator::experiments;
use zynq_estimator::hls::FpgaPart;
use zynq_estimator::sim::{simulate, EstimatorModel};
use zynq_estimator::util::Rng;

fn forall(iters: u64, base_seed: u64, f: impl Fn(u64, &mut Rng)) {
    for i in 0..iters {
        let seed = base_seed.wrapping_add(i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(seed);
        f(seed, &mut rng);
    }
}

/// Build a two-board (zynq702, zynq706) sweep of matmul+cholesky default
/// spaces. Returns the owned programs together with the axis so the sweep
/// can borrow them.
fn axis_programs() -> (BoardSpace, Vec<(usize, &'static str, TaskProgram)>) {
    let axis = BoardSpace::resolve(&["zynq702", "zynq706"]).unwrap();
    let mut programs = Vec::new();
    for (bi, t) in axis.targets.iter().enumerate() {
        programs.push((bi, "matmul", Matmul::new(256, 64).build_program(&t.board)));
        programs.push((bi, "cholesky", Cholesky::new(256, 64).build_program(&t.board)));
    }
    (axis, programs)
}

fn build_sweep<'p>(
    axis: &'p BoardSpace,
    programs: &'p [(usize, &'static str, TaskProgram)],
) -> CrossBoardSweep<'p> {
    let mut sweep = CrossBoardSweep::new();
    for (bi, app, program) in programs {
        let t = &axis.targets[*bi];
        sweep.push(
            &t.name,
            app,
            program,
            &t.board,
            &t.part,
            DseSpace::from_program(program),
        );
    }
    sweep
}

fn assert_results_bit_identical(a: &[CrossBoardResult], b: &[CrossBoardResult], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: entry count");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.board, y.board, "{what}");
        assert_eq!(x.app, y.app, "{what}");
        assert_eq!(x.stats, y.stats, "{what}: stats for {}@{}", x.app, x.board);
        assert_eq!(
            x.points.len(),
            y.points.len(),
            "{what}: point count for {}@{}",
            x.app,
            x.board
        );
        for (i, (p, q)) in x.points.iter().zip(&y.points).enumerate() {
            assert_eq!(
                p.codesign.name, q.codesign.name,
                "{what}: name at rank {i} of {}@{}",
                x.app, x.board
            );
            assert_eq!(
                p.est_ms.to_bits(),
                q.est_ms.to_bits(),
                "{what}: est_ms at rank {i} of {}@{}",
                x.app,
                x.board
            );
            assert_eq!(
                p.energy_j.to_bits(),
                q.energy_j.to_bits(),
                "{what}: energy at rank {i} of {}@{}",
                x.app,
                x.board
            );
        }
    }
}

#[test]
fn board_axis_sweeps_are_bit_identical_for_any_worker_count() {
    let (axis, programs) = axis_programs();
    let sweep = build_sweep(&axis, &programs);
    let run = |mode: usize, w: usize| match mode {
        0 => sweep.explore(Objective::Time, w),
        1 => sweep.explore_pruned(Objective::Time, w),
        _ => sweep.explore_pruned_global(Objective::Time, w),
    };
    for (mode, name) in [(0, "exhaustive"), (1, "pruned"), (2, "global-cut")] {
        let serial = run(mode, 1);
        for workers in [2, 4, 8] {
            let parallel = run(mode, workers);
            assert_results_bit_identical(&serial, &parallel, &format!("{name}/w={workers}"));
        }
    }
}

#[test]
fn pruned_board_axis_is_lossless_per_board() {
    let (axis, programs) = axis_programs();
    let sweep = build_sweep(&axis, &programs);
    let exhaustive = sweep.explore(Objective::Time, 4);
    let pruned = sweep.explore_pruned(Objective::Time, 4);
    for (e, p) in exhaustive.iter().zip(&pruned) {
        assert!(!e.points.is_empty(), "{}@{}", e.app, e.board);
        assert_eq!(
            e.points[0].est_ms.to_bits(),
            p.points[0].est_ms.to_bits(),
            "best diverged for {}@{}",
            e.app,
            e.board
        );
        assert_eq!(
            pareto_front_coords(&e.points),
            pareto_front_coords(&p.points),
            "front diverged for {}@{}",
            e.app,
            e.board
        );
        // No cross-board cut may fire in the per-board-lossless mode.
        assert_eq!(p.stats.global_cut, 0, "{}@{}", e.app, e.board);
    }
}

#[test]
fn pruned_equals_exhaustive_per_board_on_random_spaces() {
    let unroll_pool: [u32; 6] = [4, 8, 16, 32, 64, 128];
    let axis = BoardSpace::resolve(&["zynq702", "zynq706"]).unwrap();
    let programs: Vec<TaskProgram> = axis
        .targets
        .iter()
        .map(|t| Matmul::new(256, 64).build_program(&t.board))
        .collect();
    forall(10, 0xB0A2D5, |seed, rng| {
        // Random unroll subsets deliberately include saturated variants
        // (the dominance cut) and part-busting ones (the resource cut).
        let mut unrolls: Vec<u32> = Vec::new();
        for _ in 0..rng.gen_range(1, 4) {
            let u = unroll_pool[rng.gen_range(0, unroll_pool.len() as u64) as usize];
            if !unrolls.contains(&u) {
                unrolls.push(u);
            }
        }
        let space = DseSpace {
            kernels: vec![KernelSpace {
                kernel: "mxm64".into(),
                unrolls,
                max_instances: rng.gen_range(1, 4) as u32,
                try_smp: rng.next_f64() < 0.5,
            }],
            mixed: rng.next_f64() < 0.3,
        };
        let mut sweep = CrossBoardSweep::new();
        for (t, p) in axis.targets.iter().zip(&programs) {
            sweep.push(&t.name, "matmul", p, &t.board, &t.part, space.clone());
        }
        let exhaustive = sweep.explore(Objective::Time, 3);
        let pruned = sweep.explore_pruned(Objective::Time, 3);
        let global = sweep.explore_pruned_global(Objective::Time, 3);
        for (e, p) in exhaustive.iter().zip(&pruned) {
            assert!(!e.points.is_empty(), "seed {seed}: empty sweep");
            assert_eq!(
                e.points[0].est_ms.to_bits(),
                p.points[0].est_ms.to_bits(),
                "seed {seed}: best diverged for {}@{}",
                e.app,
                e.board
            );
            assert_eq!(
                pareto_front_coords(&e.points),
                pareto_front_coords(&p.points),
                "seed {seed}: front diverged for {}@{}",
                e.app,
                e.board
            );
        }
        // The incumbent mode preserves the merged (cross-board) front.
        let merge = |rs: &[CrossBoardResult]| {
            let mut all = Vec::new();
            for r in rs {
                all.extend(r.points.iter().cloned());
            }
            all
        };
        assert_eq!(
            pareto_front_coords(&merge(&exhaustive)),
            pareto_front_coords(&merge(&global)),
            "seed {seed}: merged front diverged under the global cut"
        );
    });
}

/// The pre-refactor `cross_board_matmul`: a fixed Fig. 5 loop over
/// hard-coded (board, part) pairs calling `sim::simulate` per point —
/// kept here verbatim as the regression oracle for the board-axis
/// reimplementation.
fn legacy_cross_board_matmul(n: u64) -> Vec<(String, String, f64)> {
    let mut out = Vec::new();
    for (board, part) in [
        (BoardConfig::zynq706(), FpgaPart::xc7z045()),
        (BoardConfig::zynq_ultrascale(), FpgaPart::xczu9eg()),
    ] {
        let mut best: Option<(String, f64)> = None;
        for (cd, app) in matmul::fig5_cases(n) {
            let program = app.build_program(&board);
            let mut model = EstimatorModel::new(&board);
            let Ok(res) = simulate(&program, &cd, &board, &part, Policy::Greedy, &mut model)
            else {
                continue;
            };
            let ms = res.makespan_ms();
            if best.as_ref().map(|(_, b)| ms < *b).unwrap_or(true) {
                best = Some((cd.name.clone(), ms));
            }
        }
        let two128 = CoDesign::new("2acc 128")
            .with_accel("mxm128", matmul::UNROLL_128)
            .with_accel("mxm128", matmul::UNROLL_128);
        let program = Matmul::new(n, 128).build_program(&board);
        let mut model = EstimatorModel::new(&board);
        if let Ok(res) = simulate(&program, &two128, &board, &part, Policy::Greedy, &mut model) {
            let ms = res.makespan_ms();
            if best.as_ref().map(|(_, b)| ms < *b).unwrap_or(true) {
                best = Some((two128.name.clone(), ms));
            }
        }
        let (name, ms) = best.unwrap();
        out.push((board.name.clone(), name, ms));
    }
    out
}

#[test]
fn cross_board_matmul_matches_the_prerefactor_fixed_set() {
    let new = experiments::cross_board_matmul(512).unwrap();
    let old = legacy_cross_board_matmul(512);
    assert_eq!(new.len(), old.len());
    for (a, b) in new.iter().zip(&old) {
        assert_eq!(a.0, b.0, "board name");
        assert_eq!(a.1, b.1, "decision row for {}", a.0);
        assert_eq!(a.2.to_bits(), b.2.to_bits(), "best ms for {}", a.0);
    }
}
