//! Trace elaboration — the §IV transformations that turn the basic task
//! trace into the graph the simulator executes.
//!
//! Statically elaborated here:
//! * **creation-cost tasks**: every task instance is preceded by a creation
//!   task that runs only on the SMP (the OmpSs master creates tasks
//!   sequentially, so creation tasks form a chain in program order);
//! * **transfer accounting**: per-task input/output DMA transfer counts and
//!   byte totals derived from the dependence list.
//!
//! The remaining §IV artifacts — DMA *submit* tasks (shared software
//! resource) and *output-transfer* tasks (shared channel) — exist only when
//! the scheduler actually places the task on an FPGA accelerator, which is
//! a run-time decision; the engine materializes them at dispatch
//! (`sim::engine`), exactly as the paper describes them being created for
//! device-executed tasks.

use super::deps::DepGraph;
use super::task::{TaskId, TaskProgram};

/// Per-task transfer footprint extracted from the dependence list.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Xfers {
    /// Number of input DMA descriptors (in + inout dependences).
    pub n_in: u32,
    /// Number of output DMA descriptors (out + inout dependences).
    pub n_out: u32,
    /// Total input bytes (in + inout).
    pub bytes_in: u64,
    /// Total output bytes (out + inout).
    pub bytes_out: u64,
}

/// The statically elaborated program: creation chain + compute nodes +
/// transfer footprints. Node identity convention used across the engine:
/// creation node of task `t` and compute node of task `t` are addressed by
/// `t` itself plus a node-kind discriminant.
#[derive(Clone, Debug)]
pub struct ElabProgram {
    /// Task count (creation and compute nodes share task ids).
    pub n_tasks: usize,
    /// Number of unsatisfied predecessors of each compute node:
    /// data preds (from the dependence graph) + 1 (its creation task).
    pub compute_preds: Vec<u32>,
    /// Data successors (dependence graph edges).
    pub data_succs: Vec<Vec<TaskId>>,
    /// Transfer footprint per task.
    pub xfers: Vec<Xfers>,
}

impl ElabProgram {
    /// Elaborate a program against its dependence graph.
    pub fn build(program: &TaskProgram, graph: &DepGraph) -> Self {
        assert_eq!(program.tasks.len(), graph.len());
        let n = program.tasks.len();
        let mut compute_preds = Vec::with_capacity(n);
        let mut xfers = Vec::with_capacity(n);
        for t in &program.tasks {
            compute_preds.push(graph.preds[t.id as usize].len() as u32 + 1);
            let mut x = Xfers::default();
            for d in &t.deps {
                if d.dir.reads() {
                    x.n_in += 1;
                    x.bytes_in += d.len;
                }
                if d.dir.writes() {
                    x.n_out += 1;
                    x.bytes_out += d.len;
                }
            }
            xfers.push(x);
        }
        ElabProgram {
            n_tasks: n,
            compute_preds,
            data_succs: graph.succs.clone(),
            xfers,
        }
    }

    /// Total bytes DMA'd in if every task ran on the FPGA (upper bound used
    /// by reports).
    pub fn total_bytes_in(&self) -> u64 {
        self.xfers.iter().map(|x| x.bytes_in).sum()
    }

    /// Total bytes DMA'd out if every task ran on the FPGA.
    pub fn total_bytes_out(&self) -> u64 {
        self.xfers.iter().map(|x| x.bytes_out).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::{Dep, KernelDecl, KernelProfile, Targets};

    fn prog() -> TaskProgram {
        let mut p = TaskProgram::new("t");
        p.add_kernel(KernelDecl {
            name: "k".into(),
            targets: Targets::BOTH,
            profile: KernelProfile {
                flops: 1,
                inner_trip: 1,
                in_bytes: 4,
                out_bytes: 4,
                dtype_bytes: 4,
                divsqrt: false,
            },
        });
        p
    }

    #[test]
    fn xfers_from_deps() {
        let mut p = prog();
        p.add_task(
            0,
            1,
            vec![
                Dep::input(0x100, 1024),
                Dep::input(0x200, 1024),
                Dep::inout(0x300, 512),
            ],
        );
        let g = DepGraph::build(&p);
        let e = ElabProgram::build(&p, &g);
        assert_eq!(e.xfers[0].n_in, 3); // 2 in + 1 inout
        assert_eq!(e.xfers[0].n_out, 1); // inout
        assert_eq!(e.xfers[0].bytes_in, 2560);
        assert_eq!(e.xfers[0].bytes_out, 512);
        assert_eq!(e.total_bytes_in(), 2560);
        assert_eq!(e.total_bytes_out(), 512);
    }

    #[test]
    fn compute_preds_include_creation() {
        let mut p = prog();
        p.add_task(0, 1, vec![Dep::output(0x1, 8)]);
        p.add_task(0, 1, vec![Dep::input(0x1, 8)]);
        let g = DepGraph::build(&p);
        let e = ElabProgram::build(&p, &g);
        assert_eq!(e.compute_preds[0], 1); // creation only
        assert_eq!(e.compute_preds[1], 2); // creation + data dep
        assert_eq!(e.data_succs[0], vec![1]);
    }
}
