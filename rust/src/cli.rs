//! CLI implementation — argument parsing substrate plus one function per
//! subcommand. `main.rs` is a thin dispatcher so examples, tests and
//! benches can reuse every command programmatically.

use std::collections::BTreeMap;
use std::path::PathBuf;

use crate::apps::{cholesky, matmul, stencil};
use crate::config::{AccelSpec, BoardConfig, CoDesign};
use crate::coordinator::sched::Policy;
use crate::coordinator::task::TaskProgram;
use crate::experiments;
use crate::hls::{CostModel, FpgaPart};
use crate::metrics::{utilization_report, SpeedupTable};
use crate::sim;
use crate::util::fmt_secs;

/// Minimal argument parser: positionals + `--key value` + `--flag`.
#[derive(Debug, Default)]
pub struct Args {
    /// Positional (non-flag) arguments, in order.
    pub positional: Vec<String>,
    /// `--key value` options and bare `--flag`s (flags map to an empty list).
    pub options: BTreeMap<String, Vec<String>>,
}

impl Args {
    /// Parse raw argv (the subcommand name already stripped).
    pub fn parse(argv: &[String]) -> Self {
        let mut a = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            if let Some(key) = arg.strip_prefix("--") {
                let next_is_value = argv
                    .get(i + 1)
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false);
                if next_is_value {
                    a.options
                        .entry(key.to_string())
                        .or_default()
                        .push(argv[i + 1].clone());
                    i += 2;
                } else {
                    a.options.entry(key.to_string()).or_default();
                    i += 1;
                }
            } else {
                a.positional.push(arg.clone());
                i += 1;
            }
        }
        a
    }

    /// First value of `--key`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).and_then(|v| v.first()).map(String::as_str)
    }

    /// All values of a repeatable `--key`.
    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.options
            .get(key)
            .map(|v| v.iter().map(String::as_str).collect())
            .unwrap_or_default()
    }

    /// Whether `--key` was given (with or without a value).
    pub fn has(&self, key: &str) -> bool {
        self.options.contains_key(key)
    }

    /// Integer value of `--key`, or `default` when absent; errors on a non-integer.
    pub fn u64_or(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }
}

/// Board description: `--board file.toml`, or the built-in ZC706 default.
pub fn board_from_args(args: &Args) -> anyhow::Result<BoardConfig> {
    match args.get("board") {
        Some(path) => BoardConfig::from_toml_file(std::path::Path::new(path)),
        None => Ok(BoardConfig::zynq706()),
    }
}

fn build_app_program(
    app: &str,
    n: u64,
    bs: u64,
    board: &BoardConfig,
) -> anyhow::Result<TaskProgram> {
    crate::apps::build_app_program(app, n, bs, board)
}

/// CLI help text (the command reference of the README quickstart).
pub const USAGE: &str = "zynq-estimator — coarse-grain performance estimator for Zynq-style heterogeneous systems

USAGE: zynq-estimator <command> [options]

COMMANDS (one per paper experiment, plus utilities):
  sweep          --app matmul|cholesky|lu [--n 512] [--reps 10]  Fig. 5 / Fig. 9 / LU ext.
  dma                                                           Fig. 3
  analysis-time  --app matmul|cholesky [--n 512]                Fig. 6 / §VI productivity
  paraver        --app matmul [--n 512] [--out out/]            Fig. 7 (.prv bundles)
  graph          --app cholesky [--nb 4] [--out fig8.dot]       Fig. 8 (DOT)
  estimate       --app <app> [--n N] [--bs BS] --accel k:U<u>... [--smp k]...
                 [--policy greedy|lookahead] [--real]           one co-design, served from /
                 [--memo m.json]                                 recorded into the eval memo
  trace          --app <app> [--n N] [--bs BS] --out t.jsonl    dump basic trace (§IV)
  sim-trace      --trace t.jsonl --accel k:U<u>... [--smp k]... simulate a trace file
  hls            --kernel <name> [--bs 64] [--unroll 32]        Vivado-HLS-style report
  dse            --app <app> [--objective time|energy|edp]      explore the co-design space
                 [--n 512] [--bs 64] [--top 15] [--workers N]   (paper §VII future work;
                 [--pruned] [--suite [--exhaustive]]             N=0 -> one per core;
                 [--boards zynq702,zynq706 [--global-cut]]       --pruned: bound-guided cuts;
                 [--memo m.json] [--mixed]                       --suite: sweep matmul+cholesky
                 [--order fifo|bound|ranked]                     +lu+stencil in one shared pool;
                 [--budget time|energy|area|all]                 --boards: platform as a swept
                                                                 axis + board-winner table,
                                                                 pruned unless --exhaustive;
                                                                 --memo: warm-start from / record
                                                                 into a persistent two-level eval
                                                                 memo (works with --suite and
                                                                 --boards; kernel sub-memo shares
                                                                 HLS reports + ordering priors
                                                                 across sizes and boards);
                                                                 --mixed: heterogeneous unroll
                                                                 variants per kernel instance;
                                                                 --order: bound-round candidate
                                                                 order (default ranked w/ --memo,
                                                                 else bound);
                                                                 --budget: winner-table axis for
                                                                 --boards)
                 [--resume]                                      continue an interrupted warm
                                                                 sweep from its <memo>.wal journal
                                                                 and .ckpt order checkpoint
                                                                 (requires --memo; final ranking
                                                                 and memo are bit-identical to an
                                                                 uninterrupted run)
                 [--profile]                                     per-phase timing breakdown
                                                                 (enumerate/prune/simulate/
                                                                 memo-io) + delta-reuse rate on
                                                                 stderr; stdout is unchanged
  dse memo <stats|gc|compact> --memo m.json                     memo hygiene: inspect the
                 [--keep-contexts 16] [--keep-points N]          two-level layout, LRU-by-context
                 [--keep-kernels 256]                            eviction (gc), versioned rewrite
                 [--max-bytes B [--app-floor 1]]                 (compact); retained entries stay
                                                                 bit-exact; --max-bytes switches
                                                                 gc to a serialized-size budget
                                                                 that never evicts each app's
                                                                 --app-floor most recent contexts
  serve          [--memo m.json] [--listen host:port]           estimator-as-a-service daemon:
                 [--workers N] [--save-every 8]                  NDJSON requests (estimate|energy|
                 [--max-bytes B [--app-floor 1]]                 batch|dse|memo|ping|health|
                 [--lanes 1] [--batch-window-ms 0]               shutdown), one per line on stdin
                 [--default-deadline-ms D]                       and on each TCP connection;
                 [--max-queue 64] [--max-inflight 256]           answers from one shared eval memo
                 [--max-conns 64] [--max-line-bytes 1048576]     with coalescing, kernel-group
                 [--write-timeout-ms 10000]                      memo lanes (--lanes), batch
                 [--breaker-threshold 3]                         evaluation, periodic WAL-
                                                                 journaled saves, and overload
                                                                 control: per-request deadlines
                                                                 ("deadline_ms" / the default),
                                                                 queue/in-flight/connection/line
                                                                 caps answering OVERLOADED, and a
                                                                 save circuit breaker that turns
                                                                 the daemon read-only (DEGRADED)
                                                                 after repeated save failures
                                                                 (protocol reference in README)
  energy         --app <app> --accel k:U<u>... [--smp k]...     power/energy report through the
                 [--memo m.json] [--breakdown]                   eval memo (--breakdown: per-rail
                                                                 split via detailed simulation)
  robustness     [--n 512] [--trials 25]                        decision vs HLS-error study
  analyze-prv    --prv trace.prv [--row trace.row]              bottlenecks from a Paraver trace
  lint           --trace t.jsonl                                validate a basic trace (§IV)
  measure        [--reps 5]                                     time AOT kernels via PJRT vs model
  cross-board    [--n 512]                                      ZC706 vs UltraScale+ decision
  bench-check    --baseline b.json --current c.json             gate BENCH_*.json against a
                 [--tolerance 0.2] [--strict-time]              checked-in baseline (CI)
  fuzz           [memo-json|wal-replay|board-toml|              deterministic mutation fuzzing of
                  proto-ndjson|all]                             the byte-ingesting parsers (incl.
                 [--iters 256] [--seed S] [--corpus dir]        the serve NDJSON envelopes); exit
                                                                 1 on any panic (graceful
                                                                 rejection is a pass)
  fault-recovery [--n 256] [--bs 64] [--workers N]              crash/resume study: interrupt a
                                                                 journaled sweep at every round,
                                                                 resume, verify bit-identity
  help                                                          this text

COMMON OPTIONS:
  --board <file.toml>     board description (default: built-in zynq706)
  --faults <spec[,spec]>  arm fault-injection sites for crash testing (also via the
                          ZYNQ_FAULTS env var); spec: site[@N][#HEXTAG][!error|!panic|!abort],
                          sites: memo.save memo.load wal.append wal.replay eval.point
                          board.toml sweep.round conn.read conn.write queue.admit
                          save.breaker

EXIT CODES: 0 success; 1 usage or runtime error; 2 unknown command;
            3 corrupt input file (bad board TOML / unreadable memo)
";

/// Marker wrapped around errors caused by a corrupt or invalid *input
/// file* (board TOML, memo JSON), as opposed to a usage mistake. [`run`]
/// maps these to exit code 3 so scripts and CI can tell "you typed it
/// wrong" (exit 1) from "your file is bad" (exit 3) without parsing
/// stderr.
#[derive(Debug)]
struct CorruptInput(anyhow::Error);

impl std::fmt::Display for CorruptInput {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:#}", self.0)
    }
}

impl std::error::Error for CorruptInput {}

/// Tag an error as corrupt-input (exit code 3, see [`CorruptInput`]).
fn corrupt_input(e: anyhow::Error) -> anyhow::Error {
    anyhow::Error::new(CorruptInput(e))
}

/// Dispatch one CLI invocation; returns the process exit code: 0 on
/// success, 2 for a missing/unknown command, 3 when an *input file* was
/// rejected (corrupt board TOML or memo JSON), and `Err` — exit 1 via
/// `main` — for usage and runtime errors.
pub fn run(argv: &[String]) -> anyhow::Result<i32> {
    let Some(cmd) = argv.first().map(String::as_str) else {
        println!("{USAGE}");
        return Ok(2);
    };
    let args = Args::parse(&argv[1..]);
    // Fault injection (crash testing): `--faults` specs and the
    // ZYNQ_FAULTS environment variable arm for the whole invocation; the
    // guards disarm when the command returns.
    anyhow::ensure!(
        !args.has("faults") || !args.get_all("faults").is_empty(),
        "--faults requires a spec (e.g. --faults sweep.round@2!error)"
    );
    let mut fault_guards: Vec<crate::util::faultpoint::Armed> = Vec::new();
    for spec in args.get_all("faults") {
        fault_guards.push(crate::util::faultpoint::arm(spec)?);
    }
    if let Some(guard) = crate::util::faultpoint::arm_from_env()? {
        fault_guards.push(guard);
    }
    let code = run_cmd(cmd, &args);
    drop(fault_guards);
    match code {
        Err(e) if e.is::<CorruptInput>() => {
            eprintln!("error: {e:#}");
            Ok(3)
        }
        other => other,
    }
}

fn run_cmd(cmd: &str, args: &Args) -> anyhow::Result<i32> {
    let board = board_from_args(args).map_err(corrupt_input)?;
    match cmd {
        "sweep" => cmd_sweep(args, &board),
        "dma" => cmd_dma(&board),
        "analysis-time" => cmd_analysis_time(args, &board),
        "paraver" => cmd_paraver(args, &board),
        "graph" => cmd_graph(args, &board),
        "estimate" => cmd_estimate(args, &board),
        "trace" => cmd_trace(args, &board),
        "sim-trace" => cmd_sim_trace(args, &board),
        "hls" => cmd_hls(args, &board),
        "dse" => cmd_dse(args, &board),
        "serve" => cmd_serve(args, &board),
        "energy" => cmd_energy(args, &board),
        "robustness" => cmd_robustness(args, &board),
        "analyze-prv" => cmd_analyze_prv(args),
        "lint" => cmd_lint(args),
        "measure" => cmd_measure(args, &board),
        "cross-board" => cmd_cross_board(args),
        "bench-check" => cmd_bench_check(args),
        "fuzz" => cmd_fuzz(args),
        "fault-recovery" => cmd_fault_recovery(args, &board),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(0)
        }
        other => {
            eprintln!("unknown command '{other}'\n{USAGE}");
            Ok(2)
        }
    }
}

fn cmd_sweep(args: &Args, board: &BoardConfig) -> anyhow::Result<i32> {
    let app = args.get("app").unwrap_or("matmul");
    let n = args.u64_or("n", 512)?;
    let reps = args.u64_or("reps", experiments::BOARD_REPS as u64)? as u32;
    let table: SpeedupTable = match app {
        "matmul" => experiments::fig5(n, board, reps)?,
        "cholesky" => experiments::fig9(n, board, reps)?,
        "lu" => experiments::lu_study(n, board, reps)?,
        other => anyhow::bail!("sweep supports matmul|cholesky|lu, got '{other}'"),
    };
    let fig = match app {
        "matmul" => "Fig. 5",
        "cholesky" => "Fig. 9",
        _ => "LU study (extension)",
    };
    println!(
        "{}",
        table.render(&format!("{fig}: {app} (n = {n}) — estimator vs board emulator"))
    );
    Ok(0)
}

fn cmd_dma(board: &BoardConfig) -> anyhow::Result<i32> {
    println!("== Fig. 3: DMA speedup of 2 accelerators vs 1 (in/out transfers)");
    println!(
        "{:>10}  {:>12} {:>12}  {:>12} {:>12}",
        "size", "in est", "in board", "out est", "out board"
    );
    for (label, est, brd) in experiments::fig3(board) {
        println!(
            "{label:>10}  {:>12.2} {:>12.2}  {:>12.2} {:>12.2}",
            est.input_speedup, brd.input_speedup, est.output_speedup, brd.output_speedup
        );
    }
    println!("(inputs scale with accelerators; outputs serialize — §IV)");
    Ok(0)
}

fn cmd_analysis_time(args: &Args, board: &BoardConfig) -> anyhow::Result<i32> {
    let app = args.get("app").unwrap_or("matmul");
    let n = args.u64_or("n", 512)?;
    let (meth, trad) = match app {
        "matmul" => experiments::analysis_time_matmul(n, board)?,
        "cholesky" => experiments::analysis_time_cholesky(n, board)?,
        other => anyhow::bail!("analysis-time supports matmul|cholesky, got '{other}'"),
    };
    println!("== Fig. 6: analysis time, {app} configuration set (log scale in the paper)");
    println!("  this methodology (measured):   {}", fmt_secs(meth));
    println!("  traditional flow (modelled):   {}", fmt_secs(trad));
    println!("  speedup: {:.0}x", trad / meth);
    Ok(0)
}

fn cmd_paraver(args: &Args, board: &BoardConfig) -> anyhow::Result<i32> {
    let n = args.u64_or("n", 512)?;
    let out = PathBuf::from(args.get("out").unwrap_or("out/paraver"));
    let stems = experiments::fig7(n, board, &out)?;
    println!("== Fig. 7: Paraver bundles written:");
    for s in stems {
        println!("  {}.prv/.pcf/.row", s.display());
    }
    Ok(0)
}

fn cmd_graph(args: &Args, board: &BoardConfig) -> anyhow::Result<i32> {
    let nb = args.u64_or("nb", 4)?;
    let dot = experiments::fig8(nb, board);
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &dot)?;
            println!("wrote {path} ({} bytes) — render with `dot -Tpng`", dot.len());
        }
        None => println!("{dot}"),
    }
    Ok(0)
}

fn codesign_from_args(args: &Args) -> anyhow::Result<CoDesign> {
    let mut cd = CoDesign::new("cli");
    for spec in args.get_all("accel") {
        cd.accels.push(AccelSpec::parse(spec)?);
    }
    for k in args.get_all("smp") {
        cd.smp_kernels.push(k.to_string());
    }
    Ok(cd)
}

/// Shared memo-backed path of the one-shot `estimate`/`energy` commands.
///
/// Both serve from — and record into — the same [`EvalMemo`] the warm
/// sweeps and the daemon use: the memo is the single evaluation cache.
/// With `--memo <file>` the hit/recorded status goes to **stderr** (so
/// stdout stays byte-identical between a fresh evaluation and a memo
/// hit — and identical to the daemon's `text` field for the same query);
/// without it the query runs against a transient in-memory memo.
///
/// [`EvalMemo`]: crate::dse::EvalMemo
fn run_point_query(
    args: &Args,
    board: &BoardConfig,
    program: &TaskProgram,
    app: &str,
    n: u64,
    bs: u64,
    cd: &CoDesign,
    energy_view: bool,
) -> anyhow::Result<()> {
    let part = FpgaPart::xc7z045();
    match memo_path_from_args(args)? {
        Some(memo_path) => {
            let path = std::path::Path::new(memo_path);
            let (mut memo, recovered) =
                crate::dse::EvalMemo::load_with_recovery(path).map_err(corrupt_input)?;
            if let Some(rec) = &recovered {
                eprintln!(
                    "recovered {} journaled points across {} contexts ({} committed rounds) from {}",
                    rec.n_points(),
                    rec.contexts.len(),
                    rec.rounds,
                    crate::dse::SweepJournal::wal_path(path).display(),
                );
            }
            // Journal the fresh evaluation (if any) as one committed WAL
            // round before saving, so even a crash between answer and
            // save cannot lose it — the same contract warm sweeps have.
            let mut journal = crate::dse::SweepJournal::open(path)?;
            let out = crate::service::point_query(
                program,
                board,
                &part,
                app,
                n,
                bs,
                cd,
                energy_view,
                &mut memo,
                Some(&mut journal),
            )?;
            drop(journal);
            memo.save(path)?;
            print!("{}", out.reply.text);
            eprintln!(
                "memo: {} -> {memo_path} ({} points, {} contexts, {} kernel entries)",
                if out.hit {
                    "L2 hit, 0 points evaluated"
                } else {
                    "miss, 1 point evaluated and recorded"
                },
                memo.n_points(),
                memo.n_contexts(),
                memo.n_kernel_entries(),
            );
        }
        None => {
            let mut memo = crate::dse::EvalMemo::new();
            let out = crate::service::point_query(
                program, board, &part, app, n, bs, cd, energy_view, &mut memo, None,
            )?;
            print!("{}", out.reply.text);
        }
    }
    Ok(())
}

fn cmd_estimate(args: &Args, board: &BoardConfig) -> anyhow::Result<i32> {
    let app = args
        .get("app")
        .ok_or_else(|| anyhow::anyhow!("estimate requires --app"))?;
    let n = args.u64_or("n", 512)?;
    let bs = args.u64_or("bs", 64)?;
    let program = build_app_program(app, n, bs, board)?;
    let cd = codesign_from_args(args)?;
    let policy = match args.get("policy") {
        None => Policy::Greedy,
        Some(p) => Policy::parse(p)
            .ok_or_else(|| anyhow::anyhow!("unknown policy '{p}' (greedy|lookahead)"))?,
    };
    if matches!(policy, Policy::Greedy) {
        // Default-policy estimates route through the shared evaluation
        // memo (the key space the warm sweeps and the daemon use).
        run_point_query(args, board, &program, app, n, bs, &cd, false)?;
    } else {
        // Non-default policies are outside the memo contract (the memo
        // caches the sweep engine's default-policy evaluation): run the
        // detailed simulation directly.
        if args.has("memo") {
            eprintln!("note: --memo caches the default (greedy) policy only; ignored");
        }
        let mut model = sim::EstimatorModel::new(board);
        let res = sim::simulate(&program, &cd, board, &FpgaPart::xc7z045(), policy, &mut model)?;
        println!(
            "== estimator: {app} n={n} bs={bs} accels={:?} policy={}",
            cd.accels.iter().map(|a| a.to_spec_string()).collect::<Vec<_>>(),
            policy.as_str()
        );
        print!("{}", utilization_report(&res));
    }
    if args.has("real") {
        let mean = sim::emulate_mean_ms(&program, &cd, board, experiments::BOARD_REPS)?;
        println!("board emulator mean of {} runs: {mean:.3} ms", experiments::BOARD_REPS);
    }
    Ok(0)
}

fn cmd_trace(args: &Args, board: &BoardConfig) -> anyhow::Result<i32> {
    let app = args
        .get("app")
        .ok_or_else(|| anyhow::anyhow!("trace requires --app"))?;
    let n = args.u64_or("n", 512)?;
    let bs = args.u64_or("bs", 64)?;
    let out = args
        .get("out")
        .ok_or_else(|| anyhow::anyhow!("trace requires --out <file.jsonl>"))?;
    let program = build_app_program(app, n, bs, board)?;
    crate::trace::save(&program, std::path::Path::new(out))?;
    println!(
        "wrote {} tasks ({} kernels) to {out}",
        program.tasks.len(),
        program.kernels.len()
    );
    Ok(0)
}

fn cmd_sim_trace(args: &Args, board: &BoardConfig) -> anyhow::Result<i32> {
    let path = args
        .get("trace")
        .ok_or_else(|| anyhow::anyhow!("sim-trace requires --trace <file.jsonl>"))?;
    let program = crate::trace::load(std::path::Path::new(path))?;
    let cd = codesign_from_args(args)?;
    let res = sim::estimate(&program, &cd, board)?;
    println!("== estimator on trace {path} ({} tasks)", program.tasks.len());
    print!("{}", utilization_report(&res));
    Ok(0)
}

fn cmd_hls(args: &Args, board: &BoardConfig) -> anyhow::Result<i32> {
    let kernel = args
        .get("kernel")
        .ok_or_else(|| anyhow::anyhow!("hls requires --kernel <name>"))?;
    let bs = args.u64_or("bs", 64)?;
    let unroll = args.u64_or("unroll", 32)? as u32;
    // Resolve the kernel profile from the app layer.
    let profile = match kernel {
        k if k.starts_with("mxm") => matmul::Matmul::new(bs.max(64) * 4, bs).profile(),
        "dgemm" | "dsyrk" | "dtrsm" | "dpotrf" => {
            let app = cholesky::Cholesky::new(bs * 4, bs);
            app.profiles()
                .into_iter()
                .find(|(n, _, _)| *n == kernel)
                .map(|(_, _, p)| p)
                .ok_or_else(|| anyhow::anyhow!("unknown cholesky kernel"))?
        }
        k if k.starts_with("jacobi") => stencil::Stencil::new(bs * 4, bs, 1).profile(),
        other => anyhow::bail!("unknown kernel '{other}'"),
    };
    let report = CostModel::from_board(board).estimate(kernel, &profile, unroll);
    print!("{}", report.render());
    let part = FpgaPart::xc7z045();
    let u = part.utilization(&[report.resources]);
    println!(
        "fits {}: {} (utilization {:.0}%, {} instances fit)",
        part.name,
        report.resources.fits_in(&part.effective_budget()),
        u * 100.0,
        (1.0 / u.max(1e-9)).floor().min(16.0) as u32,
    );
    Ok(0)
}

/// `--memo <path>`: `Some(path)` when given with a value; an error when
/// the flag is present but bare (silently ignoring it would drop the
/// user's intent to persist evaluations).
fn memo_path_from_args(args: &Args) -> anyhow::Result<Option<&str>> {
    if !args.has("memo") {
        return Ok(None);
    }
    args.get("memo")
        .map(Some)
        .ok_or_else(|| anyhow::anyhow!("--memo requires a file path (e.g. --memo memo.json)"))
}

/// Print the journal-recovery report of
/// [`EvalMemo::load_with_recovery`](crate::dse::EvalMemo::load_with_recovery),
/// when an interrupted sweep left committed rounds behind.
fn report_recovery(recovered: &Option<crate::dse::WalRecovery>, path: &std::path::Path) {
    if let Some(rec) = recovered {
        println!(
            "recovered {} journaled points across {} contexts ({} committed rounds) from {}",
            rec.n_points(),
            rec.contexts.len(),
            rec.rounds,
            crate::dse::SweepJournal::wal_path(path).display(),
        );
    }
}

/// `--order fifo|bound|ranked`; defaults to `ranked` when a memo is in
/// play (the warm path exists to tighten the incumbent early) and to the
/// historical `bound` otherwise.
fn order_from_args(args: &Args) -> anyhow::Result<crate::dse::OrderMode> {
    match args.get("order") {
        None => Ok(if args.has("memo") {
            crate::dse::OrderMode::Ranked
        } else {
            crate::dse::OrderMode::BoundAsc
        }),
        Some(o) => crate::dse::OrderMode::parse(o)
            .ok_or_else(|| anyhow::anyhow!("unknown order '{o}' (fifo|bound|ranked)")),
    }
}

/// `--profile` epilogue: per-phase wall-clock breakdown plus the
/// deterministic delta-reuse counters, on **stderr** so the ranking table
/// (stdout) stays machine-consumable. No-op unless `--profile` enabled
/// the profiler.
fn emit_profile(delta: crate::dse::DeltaStats) {
    if !crate::util::profile::enabled() {
        return;
    }
    let mut extra = Vec::new();
    let n = delta.hits + delta.fallbacks;
    if n > 0 {
        extra.push(format!(
            "delta-reuse: {}/{} neighbor evals ({:.1}%), evaluated-suffix fraction {:.3}",
            delta.hits,
            n,
            100.0 * delta.reuse_rate(),
            delta.suffix_fraction(),
        ));
    }
    let _ = crate::util::profile::report(&mut std::io::stderr(), &extra);
}

fn cmd_dse(args: &Args, board: &BoardConfig) -> anyhow::Result<i32> {
    if args.positional.first().map(String::as_str) == Some("memo") {
        return cmd_dse_memo(args);
    }
    let top = args.u64_or("top", 15)? as usize;
    let objective = match args.get("objective") {
        None => crate::dse::Objective::Time,
        Some(o) => crate::dse::Objective::parse(o)
            .ok_or_else(|| anyhow::anyhow!("unknown objective '{o}' (time|energy|edp)"))?,
    };
    let workers = match args.u64_or("workers", 0)? as usize {
        0 => crate::dse::default_workers(),
        w => w,
    };
    let order = order_from_args(args)?;
    anyhow::ensure!(
        !args.has("resume") || args.has("memo"),
        "--resume requires --memo <file> (resume continues a journaled warm sweep)"
    );
    if args.has("boards") {
        return cmd_dse_boards(args, objective, top, workers);
    }
    if args.has("suite") {
        return cmd_dse_suite(args, board, objective, top, workers, order);
    }
    if args.has("profile") {
        crate::util::profile::enable();
    }
    let app = args.get("app").unwrap_or("matmul");
    let n = args.u64_or("n", 512)?;
    let bs = args.u64_or("bs", 64)?;
    let program = build_app_program(app, n, bs, board)?;
    let mut space = crate::dse::DseSpace::from_program(&program);
    space.mixed = args.has("mixed");
    if let Some(memo_path) = memo_path_from_args(args)? {
        if !args.has("pruned") {
            eprintln!("note: --memo implies the bound-guided pruned (warm) path");
        }
        let path = std::path::Path::new(memo_path);
        let (mut memo, recovered) = {
            let _t = crate::util::profile::scope("memo-io");
            crate::dse::EvalMemo::load_with_recovery(path).map_err(corrupt_input)?
        };
        report_recovery(&recovered, path);
        // The session journals every evaluation round to `<memo>.wal` and
        // checkpoints the candidate order, so a crash loses at most the
        // in-flight round and `--resume` continues bit-identically.
        let mut recovery = crate::dse::RecoverySession::open(path, recovered, args.has("resume"))?;
        // Prime the HLS cache from the level-1 kernel sub-memo first, so
        // kernels characterized by any earlier run — any problem size,
        // same board — skip the cost model.
        let ctx = crate::dse::SweepContext::for_space_warm(
            &program,
            board,
            &FpgaPart::xc7z045(),
            &space,
            &memo,
        );
        let t0 = std::time::Instant::now();
        let (points, stats) = ctx.explore_warm_recoverable(
            &space,
            &mut memo,
            objective,
            workers,
            order,
            &mut recovery,
        )?;
        let secs = t0.elapsed().as_secs_f64();
        {
            let _t = crate::util::profile::scope("memo-io");
            memo.save(path)?;
        }
        print!("{}", crate::dse::render(&points, top, objective));
        println!("pruning: {}", stats.render());
        println!(
            "memo: {} hits ({} L2 point hits, {} L1 kernel hits), {} new points recorded \
             -> {memo_path} ({} points, {} contexts, {} kernel entries)",
            stats.memo_hits + stats.kernel_hits,
            stats.memo_hits,
            stats.kernel_hits,
            stats.evaluated,
            memo.n_points(),
            memo.n_contexts(),
            memo.n_kernel_entries(),
        );
        println!(
            "swept {} of {} feasible points in {:.3} s ({workers} workers, {:?} order, {} cached HLS reports)",
            stats.evaluated,
            stats.feasible_points,
            secs,
            order,
            ctx.cached_reports(),
        );
        emit_profile(crate::dse::DeltaStats {
            hits: stats.delta_hits,
            fallbacks: stats.delta_fallbacks,
            suffix_events: stats.delta_suffix_events,
            total_events: stats.delta_total_events,
        });
        return Ok(0);
    }
    let ctx = crate::dse::SweepContext::for_space(&program, board, &FpgaPart::xc7z045(), &space);
    let t0 = std::time::Instant::now();
    if args.has("pruned") {
        let (points, stats) = ctx.explore_pruned_with(&space, objective, workers, order);
        let secs = t0.elapsed().as_secs_f64();
        print!("{}", crate::dse::render(&points, top, objective));
        println!("pruning: {}", stats.render());
        println!(
            "swept {} of {} feasible points in {:.3} s ({workers} workers, {} cached HLS reports)",
            stats.evaluated,
            stats.feasible_points,
            secs,
            ctx.cached_reports(),
        );
        emit_profile(crate::dse::DeltaStats {
            hits: stats.delta_hits,
            fallbacks: stats.delta_fallbacks,
            suffix_events: stats.delta_suffix_events,
            total_events: stats.delta_total_events,
        });
        return Ok(0);
    }
    if args.has("order") {
        eprintln!("note: --order applies to pruned sweeps; ignored for the exhaustive path");
    }
    let (points, delta) = ctx.explore_with_stats(&space, objective, workers);
    let secs = t0.elapsed().as_secs_f64();
    print!("{}", crate::dse::render(&points, top, objective));
    println!(
        "swept {} points in {:.3} s ({:.0} points/s, {workers} workers, {} cached HLS reports)",
        points.len(),
        secs,
        points.len() as f64 / secs.max(1e-9),
        ctx.cached_reports(),
    );
    emit_profile(delta);
    Ok(0)
}

/// `dse --suite`: sweep the whole matmul/cholesky/lu/stencil suite through
/// one shared worker pool, with bound-guided pruning unless
/// `--exhaustive` is given. With `--memo`, the suite runs warm — memo hits
/// skip simulation, the kernel sub-memo primes every app's HLS cache, and
/// a repeated run over an unchanged suite sweeps zero points.
fn cmd_dse_suite(
    args: &Args,
    board: &BoardConfig,
    objective: crate::dse::Objective,
    top: usize,
    workers: usize,
    order: crate::dse::OrderMode,
) -> anyhow::Result<i32> {
    let n = args.u64_or("n", 512)?;
    let bs = args.u64_or("bs", 64)?;
    if let Some(app) = args.get("app") {
        eprintln!("note: --suite sweeps all four apps; --app {app} is ignored");
    }
    if args.has("mixed") {
        eprintln!("note: --mixed is not wired for --suite; ignored");
    }
    if args.has("order") && !args.has("memo") {
        eprintln!("note: --order applies to warm (--memo) suite sweeps; ignored");
    }
    let memo_arg = memo_path_from_args(args)?;
    if memo_arg.is_some() && args.has("exhaustive") {
        eprintln!("note: --memo also serves the exhaustive suite (hits skip simulation)");
    }
    let part = FpgaPart::xc7z045();
    let programs: Vec<(&str, crate::coordinator::task::TaskProgram)> = crate::apps::SUITE_APPS
        .into_iter()
        .map(|app| Ok((app, build_app_program(app, n, bs, board)?)))
        .collect::<anyhow::Result<_>>()?;
    if args.has("resume") {
        eprintln!(
            "note: --suite replays any journal on load but sweeps without checkpoints; \
             --resume has no further effect"
        );
    }
    let mut memo_state: Option<(std::path::PathBuf, crate::dse::EvalMemo)> = match memo_arg {
        Some(p) => {
            let path = std::path::PathBuf::from(p);
            // Journal replay only: salvage points committed by an
            // interrupted recoverable sweep over the same memo file.
            let (memo, recovered) =
                crate::dse::EvalMemo::load_with_recovery(&path).map_err(corrupt_input)?;
            report_recovery(&recovered, &path);
            Some((path, memo))
        }
        None => None,
    };
    let mut suite = crate::dse::SweepSuite::new();
    for (name, program) in &programs {
        let space = crate::dse::DseSpace::from_program(program);
        match &memo_state {
            Some((_, memo)) => suite.push_warm(name, program, board, &part, space, memo),
            None => suite.push(name, program, board, &part, space),
        }
    }
    let pruned = !args.has("exhaustive");
    let t0 = std::time::Instant::now();
    let results = match (&mut memo_state, pruned) {
        (Some((_, memo)), true) => suite.explore_pruned_warm(memo, objective, workers, order),
        (Some((_, memo)), false) => suite.explore_warm(memo, objective, workers),
        (None, true) => suite.explore_pruned(objective, workers),
        (None, false) => suite.explore(objective, workers),
    };
    let secs = t0.elapsed().as_secs_f64();
    let mut evaluated = 0u64;
    let mut feasible = 0u64;
    for r in &results {
        println!("==== {} (n = {n})", r.name);
        print!("{}", crate::dse::render(&r.points, top, objective));
        if pruned || memo_state.is_some() {
            println!("pruning: {}", r.stats.render());
        }
        println!();
        evaluated += r.stats.evaluated;
        feasible += r.stats.feasible_points;
    }
    if let Some((path, memo)) = &memo_state {
        memo.save(path)?;
        let hits: u64 = results.iter().map(|r| r.stats.memo_hits).sum();
        let kernel_hits: u64 = results.iter().map(|r| r.stats.kernel_hits).sum();
        println!(
            "memo: {} hits ({hits} L2 point hits, {kernel_hits} L1 kernel hits) -> {} \
             ({} points, {} contexts, {} kernel entries)",
            hits + kernel_hits,
            path.display(),
            memo.n_points(),
            memo.n_contexts(),
            memo.n_kernel_entries(),
        );
        println!("swept {evaluated} of {feasible} feasible points across the suite");
    }
    println!(
        "suite: {} apps, {} of {} feasible points evaluated in {:.3} s ({} mode, {workers} workers, one shared pool)",
        results.len(),
        evaluated,
        feasible,
        secs,
        if pruned { "pruned" } else { "exhaustive" },
    );
    Ok(0)
}

/// `dse --boards b1,b2[,...]`: the platform as a swept axis. Sweeps the
/// chosen app (or the whole suite with `--suite`) on every board of the
/// axis through one shared worker pool and prints, per (app, board), the
/// ranked points plus the per-application "which board wins at which
/// budget" table. Pruned by default — the per-board losslessness contract
/// holds — like `dse --suite`; `--exhaustive` opts out, and
/// `--global-cut` instead shares a cross-board incumbent between the
/// boards of each app (exact for the global answer only).
fn cmd_dse_boards(
    args: &Args,
    objective: crate::dse::Objective,
    top: usize,
    workers: usize,
) -> anyhow::Result<i32> {
    let n = args.u64_or("n", 512)?;
    let bs = args.u64_or("bs", 64)?;
    if args.has("mixed") || args.has("order") {
        eprintln!("note: --mixed and --order apply to single-app sweeps; ignored with --boards");
    }
    let axis = crate::board::BoardSpace::resolve(&args.get_all("boards"))?;
    let apps: Vec<&str> = if args.has("suite") {
        crate::apps::SUITE_APPS.to_vec()
    } else {
        vec![args.get("app").unwrap_or("matmul")]
    };
    let programs = crate::dse::cross::build_axis_programs(&axis, &apps, n, bs)?;
    // Pruned by default (matching `dse --suite`); `--exhaustive` opts out;
    // `--memo` warm-starts from (and records into) a persistent two-level
    // eval memo: level-2 hits skip simulation, the level-1 kernel sub-memo
    // primes HLS caches and seeds sibling-board ordering priors.
    let memo_arg = memo_path_from_args(args)?;
    let mut recovered: Option<crate::dse::WalRecovery> = None;
    let mut memo_state: Option<(std::path::PathBuf, crate::dse::EvalMemo)> = match memo_arg {
        Some(p) => {
            let path = std::path::PathBuf::from(p);
            let (memo, rec) =
                crate::dse::EvalMemo::load_with_recovery(&path).map_err(corrupt_input)?;
            report_recovery(&rec, &path);
            recovered = rec;
            Some((path, memo))
        }
        None => None,
    };
    let sweep = match &memo_state {
        Some((_, memo)) => crate::dse::cross::sweep_from_programs_warm(&axis, &programs, memo),
        None => crate::dse::cross::sweep_from_programs(&axis, &programs),
    };
    let mode = if memo_state.is_some() {
        if args.has("exhaustive") || args.has("global-cut") {
            eprintln!("note: --memo (warm mode) takes precedence over --exhaustive/--global-cut");
        }
        "warm"
    } else if args.has("global-cut") {
        "global-cut"
    } else if args.has("exhaustive") {
        "exhaustive"
    } else {
        "pruned"
    };
    let t0 = std::time::Instant::now();
    let results = match mode {
        "warm" => {
            let (path, memo) = memo_state.as_mut().expect("warm mode implies a memo");
            // Entries journal their rounds to `<memo>.wal`; `--resume`
            // restores the interrupted entry's checkpointed order so the
            // finished axis is bit-identical to an uninterrupted run.
            let mut recovery =
                crate::dse::RecoverySession::open(path, recovered.take(), args.has("resume"))?;
            let results =
                sweep.explore_pruned_warm_recoverable(memo, objective, workers, &mut recovery)?;
            memo.save(path)?;
            let hits: u64 = results.iter().map(|r| r.stats.memo_hits).sum();
            let kernel_hits: u64 = results.iter().map(|r| r.stats.kernel_hits).sum();
            println!(
                "memo: {} hits across the axis ({} L2 point hits, {} L1 kernel hits) -> {} \
                 ({} points, {} contexts, {} kernel entries)",
                hits + kernel_hits,
                hits,
                kernel_hits,
                path.display(),
                memo.n_points(),
                memo.n_contexts(),
                memo.n_kernel_entries(),
            );
            results
        }
        "global-cut" => sweep.explore_pruned_global(objective, workers),
        "pruned" => sweep.explore_pruned(objective, workers),
        _ => sweep.explore(objective, workers),
    };
    let secs = t0.elapsed().as_secs_f64();
    let mut evaluated = 0u64;
    let mut feasible = 0u64;
    for r in &results {
        println!("==== {} @ {} (n = {n})", r.app, r.board);
        print!("{}", crate::dse::render(&r.points, top, objective));
        if mode != "exhaustive" {
            println!("pruning: {}", r.stats.render());
        }
        println!();
        evaluated += r.stats.evaluated;
        feasible += r.stats.feasible_points;
    }
    let axes: Vec<crate::dse::BudgetAxis> = match args.get("budget") {
        None => vec![crate::dse::BudgetAxis::Time],
        Some("all") => vec![
            crate::dse::BudgetAxis::Time,
            crate::dse::BudgetAxis::Energy,
            crate::dse::BudgetAxis::Area,
        ],
        Some(a) => {
            let axis = crate::dse::BudgetAxis::parse(a).ok_or_else(|| {
                anyhow::anyhow!("unknown budget axis '{a}' (time|energy|area|all)")
            })?;
            vec![axis]
        }
    };
    for axis_kind in axes {
        for (app, rows) in crate::dse::board_winner_table_for(&results, axis_kind) {
            print!("{}", crate::dse::cross::render_budget_table(&app, &rows, axis_kind));
            println!();
        }
    }
    println!(
        "board axis: {} boards x {} apps, {evaluated} of {feasible} feasible points \
         evaluated in {secs:.3} s ({mode} mode, {workers} workers, one shared pool)",
        axis.targets.len(),
        apps.len(),
    );
    Ok(0)
}

/// `dse memo stats|gc|compact`: first-class hygiene for the two-level
/// evaluation memo. `stats` prints the layout (contexts, points, kernel
/// entries, per-context recency), `gc` bounds the file with
/// LRU-by-context eviction (`--keep-contexts`/`--keep-points`/
/// `--keep-kernels`; retained entries stay bit-exact), and `compact`
/// rewrites the file in the current schema version with empty contexts
/// dropped. The memo path comes from `--memo <file>` or a bare positional
/// (`dse memo stats m.json`).
fn cmd_dse_memo(args: &Args) -> anyhow::Result<i32> {
    let action = args
        .positional
        .get(1)
        .map(String::as_str)
        .ok_or_else(|| anyhow::anyhow!("dse memo requires an action: stats|gc|compact"))?;
    let path = match memo_path_from_args(args)? {
        Some(p) => p.to_string(),
        None => args.positional.get(2).cloned().ok_or_else(|| {
            anyhow::anyhow!("dse memo {action} requires --memo <file> (or a path positional)")
        })?,
    };
    for flag in ["order", "mixed", "pruned", "workers", "boards", "suite", "budget"] {
        if args.has(flag) {
            eprintln!("note: --{flag} applies to sweeps, not `dse memo` subcommands; ignored");
        }
    }
    let path = std::path::PathBuf::from(path);
    anyhow::ensure!(path.exists(), "{}: no such memo file", path.display());
    let before = std::fs::metadata(&path)?.len();
    let mut memo = crate::dse::EvalMemo::load_or_new(&path).map_err(corrupt_input)?;
    match action {
        "stats" => {
            print!("{}", memo.stats().render());
        }
        "gc" => {
            let report = if args.has("max-bytes") {
                // Byte-budget policy: evict LRU contexts (then kernel
                // entries) until the serialized memo fits, but never the
                // `--app-floor` most recent contexts of any app.
                let max_bytes = args
                    .get("max-bytes")
                    .ok_or_else(|| anyhow::anyhow!("--max-bytes requires a byte count"))?
                    .parse::<u64>()
                    .map_err(|_| anyhow::anyhow!("--max-bytes expects an integer byte count"))?
                    .min(usize::MAX as u64) as usize;
                let app_floor = args.u64_or("app-floor", 1)? as usize;
                memo.gc_bytes(max_bytes, app_floor)
            } else {
                anyhow::ensure!(
                    !args.has("app-floor"),
                    "--app-floor applies to the --max-bytes byte-budget policy"
                );
                let keep_contexts = args.u64_or("keep-contexts", 16)? as usize;
                let keep_points =
                    args.u64_or("keep-points", u64::MAX)?.min(usize::MAX as u64) as usize;
                let keep_kernels = args.u64_or("keep-kernels", 256)? as usize;
                memo.gc(keep_contexts, keep_points, keep_kernels)
            };
            memo.save(&path)?;
            let after = std::fs::metadata(&path)?.len();
            println!(
                "gc: evicted {} contexts ({} points) and {} kernel entries; {before} -> {after} bytes \
                 ({} contexts, {} points, {} kernel entries retained, all bit-exact)",
                report.evicted_contexts,
                report.evicted_points,
                report.evicted_kernels,
                memo.n_contexts(),
                memo.n_points(),
                memo.n_kernel_entries(),
            );
        }
        "compact" => {
            let dropped = memo.compact();
            memo.save(&path)?;
            let after = std::fs::metadata(&path)?.len();
            println!(
                "compact: dropped {dropped} empty contexts; {before} -> {after} bytes \
                 (schema v{})",
                crate::dse::warm::MEMO_SCHEMA_VERSION,
            );
        }
        other => anyhow::bail!("unknown memo action '{other}' (stats|gc|compact)"),
    }
    Ok(0)
}

/// `serve`: the estimator as a resident NDJSON daemon over one shared
/// evaluation memo (see [`crate::service`]). Requests arrive one JSON
/// object per line on stdin (and each TCP connection with `--listen`);
/// responses leave the same way on stdout. `--lanes N` shards the memo
/// lane by application so distinct apps evaluate concurrently;
/// `--batch-window-ms W` batches point queries arriving within W ms into
/// one worker-pool round (responses stay byte-identical either way).
/// The overload flags bound every client-exhaustible resource:
/// `--default-deadline-ms` applies a deadline to requests without their
/// own `"deadline_ms"`, `--max-queue`/`--max-inflight`/`--max-conns`/
/// `--max-line-bytes` shed excess load with structured `OVERLOADED`
/// responses, `--write-timeout-ms` bounds slow readers, and
/// `--breaker-threshold` consecutive save failures switch the daemon to
/// read-only degraded mode. Diagnostics go to stderr only. Exit code 0
/// on clean shutdown, 1 when a memo save failed (degraded — the WAL
/// retains the unsaved delta), 3 when the memo file could not be loaded.
fn cmd_serve(args: &Args, board: &BoardConfig) -> anyhow::Result<i32> {
    let listen = match (args.has("listen"), args.get("listen")) {
        (false, _) => None,
        (true, Some(addr)) => Some(addr.to_string()),
        (true, None) => anyhow::bail!("--listen requires an address (e.g. --listen 127.0.0.1:7070)"),
    };
    let max_bytes = match (args.has("max-bytes"), args.get("max-bytes")) {
        (false, _) => None,
        (true, Some(v)) => Some(
            v.parse::<u64>()
                .map_err(|_| anyhow::anyhow!("--max-bytes expects an integer byte count"))?
                .min(usize::MAX as u64) as usize,
        ),
        (true, None) => anyhow::bail!("--max-bytes requires a byte count"),
    };
    let lanes = args.u64_or("lanes", 1)?;
    if lanes == 0 || lanes > 64 {
        anyhow::bail!("--lanes expects 1..=64, got {lanes}");
    }
    let default_deadline_ms = match (args.has("default-deadline-ms"), args.get("default-deadline-ms")) {
        (false, _) => None,
        (true, Some(v)) => Some(v.parse::<u64>().map_err(|_| {
            anyhow::anyhow!("--default-deadline-ms expects an integer millisecond count")
        })?),
        (true, None) => anyhow::bail!("--default-deadline-ms requires a millisecond count"),
    };
    let max_line_bytes = args.u64_or("max-line-bytes", 1 << 20)?;
    if max_line_bytes == 0 {
        anyhow::bail!("--max-line-bytes expects a positive byte count");
    }
    let breaker_threshold = args.u64_or("breaker-threshold", 3)?;
    if breaker_threshold == 0 || breaker_threshold > u64::from(u32::MAX) {
        anyhow::bail!("--breaker-threshold expects 1..=4294967295, got {breaker_threshold}");
    }
    let cfg = crate::service::ServeConfig {
        memo_path: memo_path_from_args(args)?.map(PathBuf::from),
        listen,
        workers: args.u64_or("workers", 0)? as usize,
        save_every: args.u64_or("save-every", 8)?.max(1),
        max_bytes,
        app_floor: args.u64_or("app-floor", 1)? as usize,
        lanes: lanes as usize,
        batch_window_ms: args.u64_or("batch-window-ms", 0)?,
        default_deadline_ms,
        max_queue: args.u64_or("max-queue", 64)?.max(1) as usize,
        max_conns: args.u64_or("max-conns", 64)?.max(1) as usize,
        max_inflight: args.u64_or("max-inflight", 256)?.max(1) as usize,
        max_line_bytes: max_line_bytes.min(usize::MAX as u64) as usize,
        write_timeout_ms: args.u64_or("write-timeout-ms", 10_000)?,
        breaker_threshold: breaker_threshold as u32,
    };
    let svc = crate::service::Service::new(board.clone(), cfg).map_err(corrupt_input)?;
    crate::service::daemon::run(svc)
}

/// `bench-check`: compare a bench run's `BENCH_*.json` against a
/// checked-in baseline (see [`crate::util::bench_check`]). Prints the
/// per-leaf verdicts and exits 1 on regression so CI can gate on it.
fn cmd_bench_check(args: &Args) -> anyhow::Result<i32> {
    let baseline_path = args
        .get("baseline")
        .ok_or_else(|| anyhow::anyhow!("bench-check requires --baseline <file.json>"))?;
    let current_path = args
        .get("current")
        .ok_or_else(|| anyhow::anyhow!("bench-check requires --current <file.json>"))?;
    let tolerance: f64 = match args.get("tolerance") {
        None => 0.2,
        Some(t) => t
            .parse()
            .map_err(|_| anyhow::anyhow!("--tolerance expects a number, got '{t}'"))?,
    };
    let load = |path: &str| -> anyhow::Result<crate::util::json::Value> {
        let text = std::fs::read_to_string(path).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        crate::util::json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))
    };
    let report = crate::util::bench_check::compare(
        &load(baseline_path)?,
        &load(current_path)?,
        tolerance,
        args.has("strict-time"),
    );
    print!("{}", report.render());
    println!(
        "{current_path} vs {baseline_path}: {}",
        if report.ok() { "OK" } else { "REGRESSION" }
    );
    Ok(if report.ok() { 0 } else { 1 })
}

/// `fuzz [target]`: deterministic in-process mutation fuzzing of the
/// parsers that ingest external bytes — memo JSON, WAL journals, board
/// TOML (see [`crate::fuzz`]). Every mutated input must be either
/// accepted or rejected with an error; a panic is a bug and exits 1 with
/// the reproducing seed printed.
fn cmd_fuzz(args: &Args) -> anyhow::Result<i32> {
    let target = args.positional.first().map(String::as_str).unwrap_or("all");
    let iters = args.u64_or("iters", 256)?;
    let seed = args.u64_or("seed", 0xF0CC)?;
    let corpus = args.get("corpus").map(std::path::PathBuf::from);
    let targets: Vec<crate::fuzz::FuzzTarget> = if target == "all" {
        crate::fuzz::FuzzTarget::ALL.to_vec()
    } else {
        vec![crate::fuzz::FuzzTarget::parse(target).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown fuzz target '{target}' (memo-json|wal-replay|board-toml|proto-ndjson|all)"
            )
        })?]
    };
    let mut failures = 0usize;
    for t in targets {
        let report = crate::fuzz::run_target(t, corpus.as_deref(), iters, seed)?;
        print!("{}", report.render());
        failures += report.failures.len();
    }
    Ok(if failures == 0 { 0 } else { 1 })
}

/// `fault-recovery`: the crash/recovery acceptance study — interrupt a
/// journaled warm sweep at every round with an injected fault, resume
/// it, and verify the final ranking and saved memo are bit-identical to
/// the uninterrupted run (see [`crate::experiments::fault_recovery`]).
fn cmd_fault_recovery(args: &Args, board: &BoardConfig) -> anyhow::Result<i32> {
    let n = args.u64_or("n", 256)?;
    let bs = args.u64_or("bs", 64)?;
    let workers = match args.u64_or("workers", 0)? as usize {
        0 => crate::dse::default_workers(),
        w => w,
    };
    let rows = crate::experiments::fault_recovery::study(n, bs, board, workers)?;
    print!("{}", crate::experiments::fault_recovery::render(&rows));
    let ok = rows.iter().all(|r| r.identical);
    println!(
        "fault-recovery: {}",
        if ok {
            "all interrupted sweeps recovered bit-identically"
        } else {
            "MISMATCH — an interrupted sweep diverged after resume"
        }
    );
    Ok(if ok { 0 } else { 1 })
}

fn cmd_energy(args: &Args, board: &BoardConfig) -> anyhow::Result<i32> {
    let app = args
        .get("app")
        .ok_or_else(|| anyhow::anyhow!("energy requires --app"))?;
    let n = args.u64_or("n", 512)?;
    let bs = args.u64_or("bs", 64)?;
    let program = build_app_program(app, n, bs, board)?;
    let cd = codesign_from_args(args)?;
    if args.has("breakdown") {
        // Detailed per-rail energy split: derived from a fresh detailed
        // simulation, not the memo (the memo records totals only).
        let res = sim::estimate(&program, &cd, board)?;
        let cm = CostModel::from_board(board);
        let resources: Vec<crate::hls::Resources> = cd
            .accels
            .iter()
            .map(|a| {
                let kid = program
                    .kernel_id(&a.kernel)
                    .ok_or_else(|| anyhow::anyhow!("unknown kernel '{}'", a.kernel))?;
                Ok(cm
                    .estimate(&a.kernel, &program.kernel(kid).profile, a.unroll)
                    .resources)
            })
            .collect::<anyhow::Result<_>>()?;
        let part = FpgaPart::xc7z045();
        let util = part.utilization(&resources);
        let e = crate::power::PowerModel::default().energy(
            &res,
            &resources,
            util,
            board.fabric_freq_mhz,
        );
        println!("== energy: {app} n={n}");
        println!("  makespan:        {:.3} ms", e.makespan_s * 1e3);
        println!("  static energy:   {:.3} J", e.static_j);
        println!("  SMP dynamic:     {:.3} J", e.smp_dynamic_j);
        println!("  accel dynamic:   {:.3} J", e.accel_dynamic_j);
        println!("  DMA dynamic:     {:.3} J", e.dma_dynamic_j);
        println!("  total:           {:.3} J  (mean {:.2} W)", e.total_j(), e.mean_power_w());
        println!("  EDP:             {:.4} mJ*s", e.edp() * 1e3);
        return Ok(0);
    }
    // Default: totals view through the shared evaluation memo, identical
    // to the daemon's `energy` response.
    run_point_query(args, board, &program, app, n, bs, &cd, true)?;
    Ok(0)
}

fn cmd_robustness(args: &Args, board: &BoardConfig) -> anyhow::Result<i32> {
    let n = args.u64_or("n", 512)?;
    let trials = args.u64_or("trials", 25)? as u32;
    let errs = [0.05, 0.1, 0.2, 0.3, 0.5];
    let rows =
        crate::experiments::robustness::matmul_decision_stability(n, board, &errs, trials, 0xB0B)?;
    print!("{}", crate::experiments::robustness::render(&rows));
    Ok(0)
}

fn cmd_analyze_prv(args: &Args) -> anyhow::Result<i32> {
    let prv_path = args
        .get("prv")
        .ok_or_else(|| anyhow::anyhow!("analyze-prv requires --prv <file.prv>"))?;
    let prv = std::fs::read_to_string(prv_path)?;
    let row = match args.get("row") {
        Some(p) => Some(std::fs::read_to_string(p)?),
        None => {
            // Try the sibling .row file.
            let p = std::path::Path::new(prv_path).with_extension("row");
            p.exists().then(|| std::fs::read_to_string(p)).transpose()?
        }
    };
    let analysis = crate::trace::prv_analyze::analyze(&prv, row.as_deref())?;
    print!("{}", analysis.render());
    Ok(0)
}

fn cmd_lint(args: &Args) -> anyhow::Result<i32> {
    let path = args
        .get("trace")
        .ok_or_else(|| anyhow::anyhow!("lint requires --trace <file.jsonl>"))?;
    let program = crate::trace::load(std::path::Path::new(path))?;
    let findings = crate::trace::validate::lint(&program);
    if findings.is_empty() {
        println!(
            "{path}: clean ({} tasks, {} kernels)",
            program.tasks.len(),
            program.kernels.len()
        );
        return Ok(0);
    }
    for f in &findings {
        println!("{:?}: {}", f.severity, f.message);
    }
    let errors = findings
        .iter()
        .filter(|f| f.severity == crate::trace::validate::Severity::Error)
        .count();
    Ok(if errors > 0 { 1 } else { 0 })
}

fn cmd_measure(args: &Args, board: &BoardConfig) -> anyhow::Result<i32> {
    let reps = args.u64_or("reps", 5)? as u32;
    let rt = crate::runtime::Runtime::new(std::path::Path::new("artifacts"))
        .map_err(|e| anyhow::anyhow!("{e:#} — run `make artifacts` first"))?;
    // (artifact, bs, #inputs, matching app-kernel profile)
    let chol = cholesky::Cholesky::new(512, 64);
    let profiles = chol.profiles();
    let prof = |n: &str| profiles.iter().find(|(k, _, _)| *k == n).unwrap().2.clone();
    let cases: Vec<(&str, usize, usize, crate::coordinator::task::KernelProfile)> = vec![
        ("mxm64", 64, 3, matmul::Matmul::new(512, 64).profile()),
        ("mxm128", 128, 3, matmul::Matmul::new(512, 128).profile()),
        ("dgemm64", 64, 3, prof("dgemm")),
        ("dsyrk64", 64, 2, prof("dsyrk")),
        ("dtrsm64", 64, 2, prof("dtrsm")),
        ("dpotrf64", 64, 1, prof("dpotrf")),
    ];
    println!("== measured kernel times (PJRT CPU host) vs analytic ARM model ratios");
    println!("{:>10} {:>12} {:>14} {:>14}", "kernel", "host (ms)", "host ratio", "model ratio");
    let mut measured = Vec::new();
    for (stem, bs, ni, profile) in &cases {
        let ms = rt.time_kernel_ms(stem, *bs, *ni, reps)?;
        let cyc = crate::apps::smp_cycles_model(profile, board) as f64;
        measured.push((stem.to_string(), ms, cyc));
    }
    let (base_ms, base_cyc) = (measured[0].1, measured[0].2);
    for (stem, ms, cyc) in &measured {
        println!(
            "{:>10} {:>12.3} {:>14.2} {:>14.2}",
            stem,
            ms,
            ms / base_ms,
            cyc / base_cyc
        );
    }
    println!("(ratios are normalized to mxm64; the host is x86, so absolute times differ\n from the A9 — the paper's methodology needs only the relative costs)");
    Ok(0)
}

fn cmd_cross_board(args: &Args) -> anyhow::Result<i32> {
    let n = args.u64_or("n", 512)?;
    println!("== Cross-board study: same app, different platform, different decision");
    for (board, best, ms) in crate::experiments::cross_board_matmul(n)? {
        println!("  {board:18} best co-design: {best:12} ({ms:.1} ms estimated)");
    }
    println!("(2acc 128 is infeasible on the ZC706 — feasibility is part of the decision)");
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn args_parser_basics() {
        let a = Args::parse(&argv("--app matmul --n 256 --real --accel a:U2 --accel b:U4"));
        assert_eq!(a.get("app"), Some("matmul"));
        assert_eq!(a.u64_or("n", 0).unwrap(), 256);
        assert!(a.has("real"));
        assert_eq!(a.get_all("accel"), vec!["a:U2", "b:U4"]);
        assert_eq!(a.u64_or("missing", 7).unwrap(), 7);
        assert!(a.u64_or("app", 0).is_err());
    }

    #[test]
    fn help_and_unknown() {
        assert_eq!(run(&argv("help")).unwrap(), 0);
        assert_eq!(run(&argv("frobnicate")).unwrap(), 2);
        assert_eq!(run(&[]).unwrap(), 2);
    }

    #[test]
    fn dma_command_runs() {
        assert_eq!(run(&argv("dma")).unwrap(), 0);
    }

    #[test]
    fn hls_command_runs() {
        assert_eq!(run(&argv("hls --kernel mxm128 --bs 128 --unroll 128")).unwrap(), 0);
        assert_eq!(run(&argv("hls --kernel dtrsm --bs 64 --unroll 16")).unwrap(), 0);
        assert!(run(&argv("hls --kernel bogus")).is_err());
    }

    #[test]
    fn estimate_command_runs() {
        assert_eq!(
            run(&argv(
                "estimate --app matmul --n 256 --bs 64 --accel mxm64:U32"
            ))
            .unwrap(),
            0
        );
    }

    #[test]
    fn estimate_rejects_bad_policy() {
        assert!(run(&argv(
            "estimate --app matmul --n 256 --bs 64 --accel mxm64:U32 --policy bogus"
        ))
        .is_err());
    }

    #[test]
    fn trace_roundtrip_through_cli() {
        let dir = std::env::temp_dir().join("zynq_cli_trace");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.jsonl");
        let cmd = format!(
            "trace --app cholesky --n 256 --bs 64 --out {}",
            path.display()
        );
        assert_eq!(run(&argv(&cmd)).unwrap(), 0);
        let cmd = format!(
            "sim-trace --trace {} --accel dgemm:U16 --accel dtrsm:U16",
            path.display()
        );
        assert_eq!(run(&argv(&cmd)).unwrap(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lint_command_roundtrip() {
        let dir = std::env::temp_dir().join("zynq_cli_lint");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.jsonl");
        let cmd = format!("trace --app lu --n 256 --bs 64 --out {}", path.display());
        assert_eq!(run(&argv(&cmd)).unwrap(), 0);
        let cmd = format!("lint --trace {}", path.display());
        assert_eq!(run(&argv(&cmd)).unwrap(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dse_command_runs_serial_and_parallel() {
        assert_eq!(
            run(&argv("dse --app matmul --n 256 --bs 64 --workers 1 --top 5")).unwrap(),
            0
        );
        assert_eq!(
            run(&argv("dse --app matmul --n 256 --bs 64 --workers 2 --top 5")).unwrap(),
            0
        );
    }

    #[test]
    fn sweep_lu_runs() {
        assert_eq!(run(&argv("sweep --app lu --n 256 --reps 2")).unwrap(), 0);
    }

    #[test]
    fn dse_pruned_command_runs() {
        assert_eq!(
            run(&argv("dse --app matmul --n 256 --bs 64 --workers 2 --top 5 --pruned")).unwrap(),
            0
        );
    }

    #[test]
    fn dse_suite_command_runs_pruned_and_exhaustive() {
        assert_eq!(
            run(&argv("dse --suite --n 256 --workers 2 --top 3")).unwrap(),
            0
        );
        assert_eq!(
            run(&argv("dse --suite --n 256 --workers 2 --top 3 --exhaustive")).unwrap(),
            0
        );
    }

    #[test]
    fn dse_boards_command_runs() {
        assert_eq!(
            run(&argv(
                "dse --boards zynq702,zynq706 --n 256 --workers 2 --top 3 --pruned"
            ))
            .unwrap(),
            0
        );
        assert_eq!(
            run(&argv(
                "dse --boards zynq702,zynq706 --n 256 --workers 2 --top 3 --global-cut"
            ))
            .unwrap(),
            0
        );
        assert_eq!(
            run(&argv(
                "dse --boards zynq702,zynq706 --n 256 --workers 2 --top 3 --exhaustive"
            ))
            .unwrap(),
            0
        );
        assert!(run(&argv("dse --boards zynq9000")).is_err());
    }

    #[test]
    fn dse_memo_command_round_trips() {
        let dir = std::env::temp_dir().join("zynq_cli_memo");
        std::fs::create_dir_all(&dir).unwrap();
        let memo = dir.join("memo.json");
        std::fs::remove_file(&memo).ok();
        let cmd = format!(
            "dse --app matmul --n 256 --bs 64 --workers 2 --top 3 --mixed --memo {}",
            memo.display()
        );
        // Cold run records the memo; the warm re-run must load it back.
        assert_eq!(run(&argv(&cmd)).unwrap(), 0);
        assert!(memo.exists());
        assert_eq!(run(&argv(&cmd)).unwrap(), 0);
        std::fs::remove_dir_all(&dir).ok();
        // A bare --memo is a usage error everywhere, never a panic or a
        // silent no-op.
        assert!(run(&argv("dse --app matmul --n 256 --memo")).is_err());
        assert!(run(&argv("dse --boards zynq702 --n 256 --memo")).is_err());
    }

    #[test]
    fn dse_suite_memo_warm_round_trips() {
        let dir = std::env::temp_dir().join("zynq_cli_suite_memo");
        std::fs::create_dir_all(&dir).unwrap();
        let memo = dir.join("memo.json");
        std::fs::remove_file(&memo).ok();
        let cmd = format!(
            "dse --suite --n 256 --workers 2 --top 3 --memo {}",
            memo.display()
        );
        // Cold suite records; the repeat must load and serve it (the
        // "swept 0 of" contract is asserted end-to-end in CI by grepping
        // this command's output).
        assert_eq!(run(&argv(&cmd)).unwrap(), 0);
        assert!(memo.exists());
        assert_eq!(run(&argv(&cmd)).unwrap(), 0);
        // Exhaustive warm suite shares the same memo file.
        assert_eq!(run(&argv(&format!("{cmd} --exhaustive"))).unwrap(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dse_memo_subcommands_round_trip() {
        let dir = std::env::temp_dir().join("zynq_cli_memo_hygiene");
        std::fs::create_dir_all(&dir).unwrap();
        let memo = dir.join("memo.json");
        std::fs::remove_file(&memo).ok();
        // Record two contexts (two problem sizes of one app).
        for n in [128, 256] {
            let cmd = format!(
                "dse --app matmul --n {n} --bs 64 --workers 2 --top 3 --memo {}",
                memo.display()
            );
            assert_eq!(run(&argv(&cmd)).unwrap(), 0);
        }
        let bytes_before = std::fs::metadata(&memo).unwrap().len();
        // stats (path via --memo), gc with a tight cap (path positional),
        // then compact — the file must shrink under gc and stay loadable.
        let stats = format!("dse memo stats --memo {}", memo.display());
        assert_eq!(run(&argv(&stats)).unwrap(), 0);
        let gc = format!("dse memo gc {} --keep-contexts 1", memo.display());
        assert_eq!(run(&argv(&gc)).unwrap(), 0);
        assert!(std::fs::metadata(&memo).unwrap().len() < bytes_before);
        let compact = format!("dse memo compact {}", memo.display());
        assert_eq!(run(&argv(&compact)).unwrap(), 0);
        assert_eq!(run(&argv(&stats)).unwrap(), 0);
        // Usage errors: missing action, unknown action, missing path.
        assert!(run(&argv("dse memo")).is_err());
        let bogus = format!("dse memo defrag {}", memo.display());
        assert!(run(&argv(&bogus)).is_err());
        assert!(run(&argv("dse memo stats")).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn point_queries_share_one_memo_entry() {
        let dir = std::env::temp_dir().join("zynq_cli_point_memo");
        std::fs::create_dir_all(&dir).unwrap();
        let memo = dir.join("m.json");
        std::fs::remove_file(&memo).ok();
        let est = format!(
            "estimate --app matmul --n 256 --bs 64 --accel mxm64:U32 --memo {}",
            memo.display()
        );
        assert_eq!(run(&argv(&est)).unwrap(), 0);
        assert!(memo.exists());
        let loaded = crate::dse::EvalMemo::load_or_new(&memo).unwrap();
        assert_eq!(loaded.n_points(), 1, "one evaluation recorded");
        // The repeat and the energy view must both hit the same entry,
        // not record a second one (bit-identity of the served numbers is
        // asserted by the service conformance suite over the binary).
        assert_eq!(run(&argv(&est)).unwrap(), 0);
        let energy = format!(
            "energy --app matmul --n 256 --bs 64 --accel mxm64:U32 --memo {}",
            memo.display()
        );
        assert_eq!(run(&argv(&energy)).unwrap(), 0);
        let loaded = crate::dse::EvalMemo::load_or_new(&memo).unwrap();
        assert_eq!(loaded.n_points(), 1, "hits must not re-record");
        // The detailed breakdown view still renders (off-memo path).
        let breakdown = format!("{energy} --breakdown");
        assert_eq!(run(&argv(&breakdown)).unwrap(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn memo_gc_byte_budget_flags() {
        let dir = std::env::temp_dir().join("zynq_cli_memo_bytes");
        std::fs::create_dir_all(&dir).unwrap();
        let memo = dir.join("m.json");
        std::fs::remove_file(&memo).ok();
        for n in [128, 256] {
            let cmd = format!(
                "dse --app matmul --n {n} --bs 64 --workers 2 --top 3 --memo {}",
                memo.display()
            );
            assert_eq!(run(&argv(&cmd)).unwrap(), 0);
        }
        // A zero budget with the default per-app floor keeps exactly the
        // most recent matmul context.
        let gc = format!("dse memo gc {} --max-bytes 0", memo.display());
        assert_eq!(run(&argv(&gc)).unwrap(), 0);
        let loaded = crate::dse::EvalMemo::load_or_new(&memo).unwrap();
        assert_eq!(loaded.n_contexts(), 1, "per-app floor survives a zero budget");
        // Bare --max-bytes and misplaced --app-floor are usage errors.
        let bare = format!("dse memo gc {} --max-bytes", memo.display());
        assert!(run(&argv(&bare)).is_err());
        let misplaced = format!("dse memo gc {} --app-floor 2", memo.display());
        assert!(run(&argv(&misplaced)).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn serve_flag_validation() {
        // Bad flag shapes must fail before the daemon enters its stdin
        // loop (a full daemon session is driven by the conformance suite
        // over the real binary).
        assert!(run(&argv("serve --listen")).is_err());
        assert!(run(&argv("serve --max-bytes")).is_err());
        assert!(run(&argv("serve --memo")).is_err());
        assert!(run(&argv("serve --lanes 0")).is_err());
        assert!(run(&argv("serve --lanes 65")).is_err());
        assert!(run(&argv("serve --lanes nope")).is_err());
        assert!(run(&argv("serve --batch-window-ms nope")).is_err());
        // Overload-control flags: each must reject non-numeric or
        // out-of-range values, and --default-deadline-ms must reject a
        // bare flag (a deadline needs a millisecond count).
        assert!(run(&argv("serve --default-deadline-ms")).is_err());
        assert!(run(&argv("serve --default-deadline-ms nope")).is_err());
        assert!(run(&argv("serve --max-queue nope")).is_err());
        assert!(run(&argv("serve --max-inflight nope")).is_err());
        assert!(run(&argv("serve --max-conns nope")).is_err());
        assert!(run(&argv("serve --max-line-bytes 0")).is_err());
        assert!(run(&argv("serve --max-line-bytes nope")).is_err());
        assert!(run(&argv("serve --write-timeout-ms nope")).is_err());
        assert!(run(&argv("serve --breaker-threshold 0")).is_err());
        assert!(run(&argv("serve --breaker-threshold nope")).is_err());
    }

    #[test]
    fn dse_order_and_budget_flags() {
        assert_eq!(
            run(&argv(
                "dse --app matmul --n 256 --bs 64 --workers 2 --top 3 --pruned --order fifo"
            ))
            .unwrap(),
            0
        );
        assert_eq!(
            run(&argv(
                "dse --app matmul --n 256 --bs 64 --workers 2 --top 3 --pruned --order ranked --mixed"
            ))
            .unwrap(),
            0
        );
        assert!(run(&argv(
            "dse --app matmul --n 256 --pruned --order bogus"
        ))
        .is_err());
        assert_eq!(
            run(&argv(
                "dse --boards zynq702,zynq706 --n 256 --workers 2 --top 3 --budget all"
            ))
            .unwrap(),
            0
        );
        assert!(run(&argv("dse --boards zynq702 --n 256 --budget bogus")).is_err());
    }

    #[test]
    fn dse_boards_memo_warm_runs() {
        let dir = std::env::temp_dir().join("zynq_cli_boards_memo");
        std::fs::create_dir_all(&dir).unwrap();
        let memo = dir.join("memo.json");
        std::fs::remove_file(&memo).ok();
        let cmd = format!(
            "dse --boards zynq702,zynq706 --n 256 --workers 2 --top 3 --memo {}",
            memo.display()
        );
        assert_eq!(run(&argv(&cmd)).unwrap(), 0);
        assert!(memo.exists());
        assert_eq!(run(&argv(&cmd)).unwrap(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bench_check_command_gates() {
        let dir = std::env::temp_dir().join("zynq_cli_benchcheck");
        std::fs::create_dir_all(&dir).unwrap();
        let base = dir.join("base.json");
        let cur = dir.join("cur.json");
        std::fs::write(&base, r#"{"feasible_points": 100, "wall_s": 1.0}"#).unwrap();
        std::fs::write(&cur, r#"{"feasible_points": 101, "wall_s": 99.0}"#).unwrap();
        let cmd = format!(
            "bench-check --baseline {} --current {}",
            base.display(),
            cur.display()
        );
        assert_eq!(run(&argv(&cmd)).unwrap(), 0);
        std::fs::write(&cur, r#"{"feasible_points": 5, "wall_s": 1.0}"#).unwrap();
        assert_eq!(run(&argv(&cmd)).unwrap(), 1);
        assert!(run(&argv("bench-check --baseline missing.json")).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dse_resume_requires_memo() {
        assert!(run(&argv("dse --app matmul --n 256 --resume")).is_err());
        assert!(run(&argv("dse --boards zynq702 --n 256 --resume")).is_err());
    }

    #[test]
    fn dse_resume_flag_runs_clean_without_a_journal() {
        let dir = std::env::temp_dir().join("zynq_cli_resume_clean");
        std::fs::create_dir_all(&dir).unwrap();
        let memo = dir.join("memo.json");
        std::fs::remove_file(&memo).ok();
        let cmd = format!(
            "dse --app matmul --n 256 --bs 64 --workers 2 --top 3 --resume --memo {}",
            memo.display()
        );
        // No journal or checkpoint exists: --resume degrades to a plain
        // warm run, twice (the second is all memo hits).
        assert_eq!(run(&argv(&cmd)).unwrap(), 0);
        assert!(memo.exists());
        assert_eq!(run(&argv(&cmd)).unwrap(), 0);
        // A successful save cleans up both sidecars.
        assert!(!dir.join("memo.json.wal").exists());
        assert!(!dir.join("memo.json.ckpt").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_board_toml_exits_3() {
        let dir = std::env::temp_dir().join("zynq_cli_badboard");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("board.toml");
        std::fs::write(&path, "this is { not [ toml").unwrap();
        let cmd = format!("dma --board {}", path.display());
        assert_eq!(run(&argv(&cmd)).unwrap(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn faults_flag_usage_errors() {
        // Bare --faults and malformed specs are usage errors (exit 1 via
        // Err), not silent no-ops.
        assert!(run(&argv("dma --faults")).is_err());
        assert!(run(&argv("dma --faults site@x")).is_err());
        // A well-formed spec for a site that is never hit is harmless.
        assert_eq!(run(&argv("dma --faults cli.fictional.site!error")).unwrap(), 0);
    }

    #[test]
    fn fuzz_command_smoke() {
        assert_eq!(run(&argv("fuzz memo-json --iters 16 --seed 7")).unwrap(), 0);
        assert_eq!(run(&argv("fuzz wal-replay --iters 16 --seed 7")).unwrap(), 0);
        assert_eq!(run(&argv("fuzz board-toml --iters 16 --seed 7")).unwrap(), 0);
        assert_eq!(run(&argv("fuzz proto-ndjson --iters 16 --seed 7")).unwrap(), 0);
        assert!(run(&argv("fuzz bogus-target")).is_err());
    }

    #[test]
    fn graph_command_writes_dot() {
        let dir = std::env::temp_dir().join("zynq_cli_dot");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fig8.dot");
        let cmd = format!("graph --app cholesky --nb 4 --out {}", path.display());
        assert_eq!(run(&argv(&cmd)).unwrap(), 0);
        assert!(std::fs::read_to_string(&path).unwrap().contains("digraph"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
