"""Layer-2 JAX model: the application compute graphs, composed from the
Layer-1 Pallas kernels.

Everything here is build-time only. `aot.py` lowers the jitted functions to
HLO text; the Rust runtime loads and executes the artifacts, and Python is
never on the request path.

The per-task tile functions (`mxm_block_fn`, `gemm_fn`, ...) are the units
the Rust coordinator invokes — one artifact per OmpSs kernel, exactly
mirroring the accelerator granularity of the paper. `matmul_full` is the
fused whole-matrix variant used to validate the L2 composition and to
demonstrate the HBM->VMEM BlockSpec schedule.
"""

import jax.numpy as jnp

from .kernels import chol, mxm, stencil


# --- per-task tile functions (one artifact per OmpSs kernel) -----------------

def mxm_block_fn(a, b, c):
    """mxmBlock (Fig. 1): C' = A @ B + C. Artifact stems: mxm64 / mxm128."""
    return (mxm.mxm_block(a, b, c),)


def mxm_block_bf16_fn(a, b, c):
    """bf16-multiply mxmBlock variant. Artifact stem: mxm128_bf16."""
    return (mxm.mxm_block_bf16(a, b, c),)


def gemm_fn(a, b, c):
    """dgemm tile: C' = C - A @ B^T. Artifact stem: dgemm64."""
    return (chol.gemm_tile(a, b, c),)


def syrk_fn(a, c):
    """dsyrk tile: C' = C - A @ A^T. Artifact stem: dsyrk64."""
    return (chol.syrk_tile(a, c),)


def trsm_fn(l, b):
    """dtrsm tile: B' = B @ L^-T. Artifact stem: dtrsm64."""
    return (chol.trsm_tile(l, b),)


def potrf_fn(a):
    """dpotrf tile: L = chol(A). Artifact stem: dpotrf64 (SMP-side kernel,
    compiled for end-to-end numeric validation)."""
    return (chol.potrf_tile(a),)


def jacobi_fn(c, n, s, w, e):
    """jacobiBlock tile. Artifact stem: jacobi64."""
    return (stencil.jacobi_tile(c, n, s, w, e),)


# --- fused whole-matrix model (L2 composition check) --------------------------

def matmul_full(a, b):
    """C = A @ B over the full matrix via the gridded Pallas kernel.

    The donated-output / fusion story of DESIGN.md section 5 (L2): one
    pallas_call, no intermediate HBM round-trips.
    """
    return (mxm.matmul_tiled(a, b, bm=128, bn=128, bk=128),)


def cholesky_full(a):
    """Blocked right-looking Cholesky over a full SPD matrix, composed from
    the four tile kernels — validates that the L1 kernel family assembles
    into the paper's application. Unrolled at trace time (bs fixed 64)."""
    n = a.shape[0]
    bs = 64
    nb = n // bs
    tiles = {}
    for i in range(nb):
        for j in range(nb):
            tiles[(i, j)] = a[i * bs:(i + 1) * bs, j * bs:(j + 1) * bs]
    for k in range(nb):
        for j in range(k):
            tiles[(k, k)] = chol.syrk_tile(tiles[(k, j)], tiles[(k, k)])
        tiles[(k, k)] = chol.potrf_tile(tiles[(k, k)])
        for i in range(k + 1, nb):
            for j in range(k):
                tiles[(i, k)] = chol.gemm_tile(
                    tiles[(i, j)], tiles[(k, j)], tiles[(i, k)]
                )
        for i in range(k + 1, nb):
            tiles[(i, k)] = chol.trsm_tile(tiles[(k, k)], tiles[(i, k)])
    rows = [
        jnp.concatenate([tiles[(i, j)] if j <= i else jnp.zeros((bs, bs), a.dtype)
                         for j in range(nb)], axis=1)
        for i in range(nb)
    ]
    return (jnp.concatenate(rows, axis=0),)
