//! Paraver trace analyzer — the programmatic version of the paper's
//! "Paraver traces can be visualized and compared to detect potential
//! bottlenecks in the parallel and heterogeneous execution" (§VI).
//!
//! Parses `.prv` files (ours or any state-record trace using the same
//! subset) and reports per-row utilization, the longest idle gap and the
//! bottleneck resource — the numbers an analyst reads off the Fig. 7
//! timelines by eye.

use std::collections::BTreeMap;

/// Per-row (device) statistics extracted from a trace.
#[derive(Clone, Debug)]
pub struct RowStats {
    /// Row (thread) index in the trace.
    pub row: u32,
    /// Row label from the `.row` file (or generated).
    pub label: String,
    /// Total busy time, ns.
    pub busy_ns: u64,
    /// Busy time over trace duration.
    pub busy_fraction: f64,
    /// Longest idle gap, ns.
    pub longest_idle_ns: u64,
    /// Number of busy segments.
    pub segments: usize,
}

/// Whole-trace analysis.
#[derive(Clone, Debug)]
pub struct PrvAnalysis {
    /// Trace duration, ns.
    pub duration_ns: u64,
    /// Per-row statistics, trace order.
    pub rows: Vec<RowStats>,
}

impl PrvAnalysis {
    /// The busiest row — the resource limiting the execution.
    pub fn bottleneck(&self) -> Option<&RowStats> {
        self.rows
            .iter()
            .max_by(|a, b| a.busy_fraction.partial_cmp(&b.busy_fraction).unwrap())
    }

    /// Human-readable report (the `analyze-prv` CLI output).
    pub fn render(&self) -> String {
        let mut out = format!(
            "trace duration {:.3} ms, {} rows\n",
            self.duration_ns as f64 / 1e6,
            self.rows.len()
        );
        for r in &self.rows {
            out.push_str(&format!(
                "  row {:>2} {:24} busy {:>5.1}%  segs {:>6}  longest idle {:>9.3} ms\n",
                r.row,
                r.label,
                r.busy_fraction * 100.0,
                r.segments,
                r.longest_idle_ns as f64 / 1e6
            ));
        }
        if let Some(b) = self.bottleneck() {
            out.push_str(&format!(
                "bottleneck: {} ({:.1}% busy)\n",
                b.label,
                b.busy_fraction * 100.0
            ));
        }
        out
    }
}

/// Parse a `.prv` body (+ optional `.row` labels) into an analysis.
pub fn analyze(prv: &str, row_labels: Option<&str>) -> anyhow::Result<PrvAnalysis> {
    let mut lines = prv.lines();
    let header = lines.next().ok_or_else(|| anyhow::anyhow!("empty trace"))?;
    if !header.starts_with("#Paraver") {
        anyhow::bail!("not a Paraver trace (missing #Paraver header)");
    }
    let duration_ns: u64 = header
        .split_once("):")
        .ok_or_else(|| anyhow::anyhow!("malformed header"))?
        .1
        .split(':')
        .next()
        .unwrap()
        .parse()
        .map_err(|_| anyhow::anyhow!("bad duration in header"))?;

    // Busy intervals per row from state records with state != 0.
    let mut busy: BTreeMap<u32, Vec<(u64, u64)>> = BTreeMap::new();
    for (ln, line) in lines.enumerate() {
        if !line.starts_with("1:") {
            continue; // events and comments are ignored here
        }
        let f: Vec<&str> = line.split(':').collect();
        if f.len() != 8 {
            anyhow::bail!("line {}: malformed state record", ln + 2);
        }
        let row: u32 = f[1].parse().map_err(|_| anyhow::anyhow!("bad row"))?;
        let begin: u64 = f[5].parse().map_err(|_| anyhow::anyhow!("bad begin"))?;
        let end: u64 = f[6].parse().map_err(|_| anyhow::anyhow!("bad end"))?;
        let state: u32 = f[7].parse().map_err(|_| anyhow::anyhow!("bad state"))?;
        if state != 0 {
            busy.entry(row).or_default().push((begin, end));
        } else {
            busy.entry(row).or_default();
        }
    }

    let labels: Vec<String> = row_labels
        .map(|t| t.lines().skip(1).map(|s| s.to_string()).collect())
        .unwrap_or_default();

    let mut rows = Vec::new();
    for (row, mut iv) in busy {
        iv.sort_unstable();
        let busy_ns: u64 = iv.iter().map(|(b, e)| e - b).sum();
        let mut longest_idle = 0u64;
        let mut cursor = 0u64;
        for &(b, e) in &iv {
            if b > cursor {
                longest_idle = longest_idle.max(b - cursor);
            }
            cursor = cursor.max(e);
        }
        if duration_ns > cursor {
            longest_idle = longest_idle.max(duration_ns - cursor);
        }
        let label = labels
            .get(row as usize - 1)
            .cloned()
            .unwrap_or_else(|| format!("row {row}"));
        rows.push(RowStats {
            row,
            label,
            busy_ns,
            busy_fraction: if duration_ns > 0 {
                busy_ns as f64 / duration_ns as f64
            } else {
                0.0
            },
            longest_idle_ns: longest_idle,
            segments: iv.len(),
        });
    }
    Ok(PrvAnalysis { duration_ns, rows })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::matmul::Matmul;
    use crate::config::{BoardConfig, CoDesign};
    use crate::sim::estimate;
    use crate::trace::paraver;

    fn bundle(cd: &CoDesign, bs: u64) -> (String, String) {
        let b = BoardConfig::zynq706();
        let app = Matmul::new(512, bs);
        let p = app.build_program(&b);
        let r = estimate(&p, cd, &b).unwrap();
        (paraver::to_prv(&p, &b, &r), paraver::to_row(&b, &r))
    }

    #[test]
    fn analyzes_own_output() {
        let cd = CoDesign::new("1acc").with_accel("mxm64", 32);
        let (prv, row) = bundle(&cd, 64);
        let a = analyze(&prv, Some(&row)).unwrap();
        assert!(a.duration_ns > 0);
        // The single accelerator is the bottleneck of an FPGA-only run.
        let b = a.bottleneck().unwrap();
        assert!(b.label.contains("FPGA acc 0"), "bottleneck: {}", b.label);
        assert!(b.busy_fraction > 0.8);
    }

    #[test]
    fn two_accels_split_load() {
        let cd = CoDesign::new("2acc")
            .with_accel("mxm64", 32)
            .with_accel("mxm64", 32);
        let (prv, row) = bundle(&cd, 64);
        let a = analyze(&prv, Some(&row)).unwrap();
        let accels: Vec<&RowStats> = a
            .rows
            .iter()
            .filter(|r| r.label.contains("FPGA acc"))
            .collect();
        assert_eq!(accels.len(), 2);
        let (f0, f1) = (accels[0].busy_fraction, accels[1].busy_fraction);
        assert!((f0 - f1).abs() < 0.15, "imbalanced: {f0} vs {f1}");
    }

    #[test]
    fn rejects_garbage() {
        assert!(analyze("", None).is_err());
        assert!(analyze("not a trace\n", None).is_err());
        assert!(analyze("#Paraver (x):abc:1(1):1:1(1:1)\n", None).is_err());
    }

    #[test]
    fn render_mentions_bottleneck() {
        let cd = CoDesign::new("1acc").with_accel("mxm64", 32);
        let (prv, row) = bundle(&cd, 64);
        let a = analyze(&prv, Some(&row)).unwrap();
        assert!(a.render().contains("bottleneck"));
    }
}
