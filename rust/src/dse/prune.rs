//! Bound-guided pruned enumeration — cutting the co-design space *before*
//! evaluation.
//!
//! The paper defers design-space exploration strategy ("a design space
//! exploration strategy should be analyzed to reduce the amount of
//! possible solutions", §I; §VII). With per-point evaluation now parallel
//! and rebuild-free (`dse::sweep`), enumeration itself is the wall on big
//! spaces. This module cuts the cartesian [`DseSpace`] odometer with three
//! lossless prunes, applied in increasing order of cost:
//!
//! 1. **Resource-feasibility cuts.** Every accelerator variant's
//!    [`Resources`] come from the memoized HLS reports of the shared
//!    [`SweepContext`]. Variants that do not fit the
//!    [`FpgaPart`](crate::hls::FpgaPart) alone
//!    are dropped before the odometer starts, and during enumeration a
//!    running prefix sum abandons a whole odometer subtree as soon as the
//!    partial accelerator mix exceeds the effective budget (resources are
//!    additive, so no completion of an infeasible prefix can fit). The
//!    exhaustive path assembles and checks every candidate; this one
//!    never materializes the infeasible ones.
//!
//! 2. **Dominance cuts between unroll variants.** A variant that is no
//!    better in every HLS-reported latency (compute, input and output
//!    transfer wall-clock times) *and* no cheaper in every resource class
//!    than a sibling variant — strictly worse somewhere — never
//!    enumerates: the
//!    sibling-substituted co-design is itself part of the space and, task
//!    for task, is served at least as fast with at least as little area.
//!    With the analytic cost model this fires for unrolls past the
//!    pipeline's saturation point, where extra unroll only deepens the
//!    pipeline and burns area. One caveat keeps this cut in a weaker
//!    class than the other two: when the substituted variant's timing is
//!    *strictly* better (not merely equal), the argument assumes the
//!    greedy event-driven schedule is monotone in per-task duration,
//!    which discrete schedulers do not guarantee in general
//!    (Graham-style timing anomalies). The cut is therefore
//!    model-justified rather than proof-carried, and its losslessness is
//!    enforced *empirically*: the property tests compare pruned vs
//!    exhaustive best points and Pareto fronts on randomized spaces that
//!    deliberately include saturated (dominated) variants. For
//!    timing-equal dominated variants — the common saturation case — the
//!    simulation is bit-identical and the cut is exact.
//!
//! 3. **Lower-bound cuts.** Reusing [`metrics::bounds`]: a candidate whose
//!    makespan lower bound and (static-power × bound) energy lower bound
//!    are both strictly dominated by an already-evaluated point can appear
//!    on neither the time-energy Pareto front nor at the top of any
//!    ranking (time, energy, or EDP — all three are monotone in the two
//!    bounded axes), so it is skipped without simulation.
//!
//! # Determinism contract
//!
//! Bound cuts depend on what has been evaluated "so far", which is racy if
//! best-so-far is shared freely between threads. To keep the bit-identical
//! ranked-output contract of [`SweepContext::explore`], candidates are
//! processed in **chunk-synchronous rounds**: candidates are ordered by
//! ascending lower bound (deterministic), each round takes a fixed-size
//! chunk per application, skip decisions consult only the Pareto frontier
//! frozen at the previous round barrier, and the surviving chunk is
//! evaluated by the parallel worker pool. Which points get evaluated — and
//! therefore the full returned ranking — is identical for any worker
//! count, including one (asserted by `rust/tests/prune_soundness.rs`).
//!
//! The resource cuts are exact and the bound cut is provably lossless
//! (the bounds are true lower bounds of the simulated point); the
//! dominance cut is lossless modulo the scheduler-monotonicity caveat
//! above. Net guarantee, asserted on every tested space: the pruned sweep
//! returns the same best co-design and the same time-energy Pareto front
//! as the exhaustive sweep while simulating strictly fewer points (counts
//! are reported in [`PruneStats`] and by `benches/dse_suite.rs`).
//!
//! # Grouped (cross-board) sweeps
//!
//! Multi-job sweeps may opt jobs into a shared **incumbent group**
//! (`explore_pruned_grouped`): jobs of one group — e.g. the same
//! application swept on several boards — additionally consult a frontier
//! fed by every job in the group. The group-wide best point and Pareto
//! front stay exact; per-job fronts of grouped jobs may lose points (a
//! candidate dominated by another board's point is skipped), which is why
//! the default cross-board path keeps every job ungrouped and the group
//! mode is an explicit opt-in for "global answer only" queries.
//!
//! [`metrics::bounds`]: crate::metrics::bounds

use crate::config::CoDesign;
use crate::hls::Resources;
use crate::metrics::bounds::bounds;
use crate::sim::time::{ps_to_ms, Ps};

use super::ckpt::RecoverySession;
use super::sweep::SweepContext;
use super::warm::EvalMemo;
use super::{describe, DsePoint, DseSpace, KernelSpace, Objective, PointOutcome};

/// How the bound-guided rounds order their candidate stream. Ordering
/// changes *when* a candidate is considered — hence how early the
/// incumbent frontier tightens and how many candidates the (lossless)
/// bound cut skips — never *what* the sweep returns as best point and
/// Pareto front. Every mode is deterministic for any worker count: the
/// order is a pure function of the candidates and their bounds, and the
/// round-barrier semantics are unchanged.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum OrderMode {
    /// Enumeration (FIFO) order — the baseline `benches/warm_start.rs`
    /// compares the guided orders against.
    Fifo,
    /// Ascending lower bound under the sweep objective — the PR-2
    /// behaviour, and still the default of [`SweepContext::explore_pruned`].
    #[default]
    BoundAsc,
    /// Cheap-feature ranked order: ascending **predicted** score, where
    /// the prediction inflates the lower bound by calibration-free
    /// features already in hand — critical-path ratio, fabric utilization
    /// and instance count from the cached HLS reports. Processing the
    /// likely-best candidates first tightens the incumbent earlier, so
    /// the bound cut fires sooner and cuts deeper on large
    /// (mixed-variant) spaces.
    Ranked,
}

impl OrderMode {
    /// Parse a CLI order name (`fifo` | `bound` | `ranked`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "fifo" => Some(OrderMode::Fifo),
            "bound" => Some(OrderMode::BoundAsc),
            "ranked" => Some(OrderMode::Ranked),
            _ => None,
        }
    }

    /// The CLI/protocol name this mode parses back from —
    /// `OrderMode::parse(m.as_str()) == Some(m)` for every variant.
    pub fn as_str(&self) -> &'static str {
        match self {
            OrderMode::Fifo => "fifo",
            OrderMode::BoundAsc => "bound",
            OrderMode::Ranked => "ranked",
        }
    }
}

/// Candidates evaluated per application per round of the bound-guided
/// sweep. A *fixed* chunk size (rather than one derived from the worker
/// count) is what makes the bound cut deterministic: the skip decision for
/// a candidate depends only on which round it lands in, never on thread
/// timing. Small enough that even the default 17-point per-app spaces get
/// a post-incumbent round for the cut to act on; in a suite sweep the
/// per-round work list is the *sum* of the apps' chunks, so the shared
/// pool still sees wide rounds.
const ROUND_CHUNK: usize = 8;

/// Marker error returned when a sweep was cancelled at a round barrier
/// (see [`run_rounds`]'s `cancel` hook). In-flight rounds always complete
/// before the check fires, so a cancelled sweep has evaluated a
/// deterministic prefix of its rounds — and, because memo recording
/// happens only after a sweep finishes, a cancelled sweep leaves the memo
/// untouched. Callers (the service daemon's deadline path) downcast to
/// this type to classify the abort as `TIMEOUT` rather than a failure.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SweepCancelled;

impl std::fmt::Display for SweepCancelled {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "sweep cancelled at a round barrier")
    }
}

impl std::error::Error for SweepCancelled {}

/// Relative safety margin applied to the energy lower bound so that
/// floating-point summation-order differences between the bound and the
/// integrated energy report can never flip a strict comparison. The real
/// slack of the bound is orders of magnitude larger than 1e-9.
const ENERGY_LB_MARGIN: f64 = 1.0 - 1e-9;

/// Where the points of a pruned sweep went. All counters refer to one
/// `(program, space)` pair; `feasible_points` is exactly the number of
/// candidates the exhaustive [`SweepContext::explore`] would simulate
/// (minus the unrunnable ones it also skips).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PruneStats {
    /// Raw cartesian size of the space (including infeasible combinations).
    pub space_points: u64,
    /// Candidates that fit the FPGA part — what exhaustive enumeration
    /// yields and the exhaustive sweep evaluates. Computed by a pure
    /// counting odometer over the unpruned option footprints: it walks
    /// O(`feasible_points`) nodes doing a resource add + compare each (a
    /// few ns per node), which is four-plus orders of magnitude cheaper
    /// than simulating a point — the statistic costs a negligible slice
    /// of even a fully-pruned sweep.
    pub feasible_points: u64,
    /// Unroll variants dropped by the dominance cut (per kernel, summed).
    pub dominated_variants: u64,
    /// Feasible candidates never enumerated because they contained a
    /// dominated — or byte-identical duplicate — unroll variant
    /// (`feasible_points - enumerated`).
    pub dominance_cut: u64,
    /// Infeasible candidates skipped without being materialized (variant
    /// and odometer-subtree resource cuts).
    pub resource_cut: u64,
    /// Enumerated candidates skipped by the lower-bound test.
    pub bound_cut: u64,
    /// Enumerated candidates skipped by the **cross-job incumbent** of a
    /// grouped sweep (see [`CrossBoardSweep`](super::CrossBoardSweep)):
    /// their bounds were strictly dominated by a point evaluated by
    /// *another* job of the same group. Always zero in ungrouped sweeps;
    /// when non-zero, per-job Pareto fronts are no longer guaranteed
    /// complete — only the group-wide front and best point are.
    pub global_cut: u64,
    /// Candidates where some kernel had nowhere to run (also skipped by
    /// the exhaustive path).
    pub unrunnable: u64,
    /// Candidates actually simulated.
    pub evaluated: u64,
    /// Warm-start hits: candidates served bit-identically from the
    /// [`EvalMemo`](super::EvalMemo) without re-simulation. They appear in
    /// the returned ranking but not in `evaluated`. Always zero in cold
    /// sweeps.
    pub memo_hits: u64,
    /// Bound cuts that only the warm-seeded frontier could justify: the
    /// candidate's bounds were strictly dominated by a memo-hit point and
    /// by no point evaluated in *this* run. Always zero in cold sweeps.
    pub seeded_cut: u64,
    /// Level-1 warm-start hits: `(kernel, unroll)` HLS reports served from
    /// the kernel sub-memo when the context was primed
    /// ([`SweepContext::prime_with_memo`]) instead of re-running the cost
    /// model — the cross-size/cross-run reuse counter. Always zero for
    /// contexts primed cold.
    pub kernel_hits: u64,
    /// Candidates whose [`OrderMode::Ranked`] position came from a level-1
    /// per-task occupancy prior (cross-size or sibling-board statistics)
    /// rather than their own cheap rank features. Ordering only — never a
    /// cut source. Always zero without a memo.
    pub prior_ordered: u64,
    /// Candidates whose evaluation **panicked** and was quarantined by the
    /// worker-isolation layer ([`PointOutcome::Poisoned`]): they enter no
    /// frontier, no ranking and no memo, and — because a panic is a
    /// deterministic property of the point, not of scheduling — the
    /// poisoned set is identical for any worker count. Non-zero only under
    /// injected faults (`eval.point`) or genuine model bugs.
    pub poisoned: u64,
    /// Evaluations served by the incremental (delta) path: the candidate
    /// differed from its chain head in one kernel's option, and the
    /// simulator resumed the head's checkpointed schedule prefix instead
    /// of re-running the whole DAG (bit-identical to scratch; see
    /// [`sweep::DeltaStats`](super::DeltaStats)). Chain partitioning is
    /// static over the candidate list, so this counter is identical for
    /// any worker count.
    pub delta_hits: u64,
    /// Neighbor-chain evaluations that fell back to a scratch run (no
    /// provably safe checkpoint, a forced `delta.plan` fault, or a
    /// poisoned chain head).
    pub delta_fallbacks: u64,
    /// Events the delta hits actually replayed (suffix only) — with
    /// `delta_total_events`, the evaluated-suffix fraction gated in
    /// `BENCH_engine.json`.
    pub delta_suffix_events: u64,
    /// Events a scratch run of the delta-hit points would process.
    pub delta_total_events: u64,
}

impl PruneStats {
    /// Candidates that survived enumeration (dominance + resource cuts)
    /// and entered the bound-guided evaluation phase.
    pub fn enumerated(&self) -> u64 {
        self.feasible_points - self.dominance_cut
    }

    /// One-line human summary used by the CLI and benches. Warm-start
    /// counters (memo hits, seeded-frontier cuts) appear only when they
    /// fired, so cold-sweep output is unchanged.
    pub fn render(&self) -> String {
        let global = if self.global_cut > 0 {
            format!(", global {}", self.global_cut)
        } else {
            String::new()
        };
        let seeded = if self.seeded_cut > 0 {
            format!(", seeded {}", self.seeded_cut)
        } else {
            String::new()
        };
        let memo = if self.memo_hits > 0 {
            format!(" + {} memo hits", self.memo_hits)
        } else {
            String::new()
        };
        let kernel = if self.kernel_hits > 0 {
            format!(" + {} kernel hits", self.kernel_hits)
        } else {
            String::new()
        };
        let poisoned = if self.poisoned > 0 {
            format!(", poisoned {}", self.poisoned)
        } else {
            String::new()
        };
        let delta = if self.delta_hits + self.delta_fallbacks > 0 {
            format!(
                " + delta {}/{}",
                self.delta_hits,
                self.delta_hits + self.delta_fallbacks
            )
        } else {
            String::new()
        };
        format!(
            "space {} -> feasible {} -> enumerated {} -> evaluated {}{memo}{kernel}{delta} \
             (cuts: resource {}, dominance {} [{} variants], bound {}{seeded}{global}, \
             unrunnable {}{poisoned})",
            self.space_points,
            self.feasible_points,
            self.enumerated(),
            self.evaluated,
            self.resource_cut,
            self.dominance_cut,
            self.dominated_variants,
            self.bound_cut,
            self.unrunnable,
        )
    }
}

/// One surviving accelerator variant of a kernel, with the data the
/// odometer needs (resources for the prefix cut, timing for dominance).
/// Latencies are wall-clock picoseconds, not cycles, so the dominance
/// comparison stays correct even if the cost model ever derates the
/// achieved clock per variant (every variant carries its own `fmax_mhz`).
#[derive(Clone, Debug)]
struct Variant {
    unroll: u32,
    res: Resources,
    compute_ps: Ps,
    in_ps: Ps,
    out_ps: Ps,
}

fn dominates(b: &Variant, a: &Variant) -> bool {
    let no_worse = b.compute_ps <= a.compute_ps
        && b.in_ps <= a.in_ps
        && b.out_ps <= a.out_ps
        && b.res.luts <= a.res.luts
        && b.res.ffs <= a.res.ffs
        && b.res.dsps <= a.res.dsps
        && b.res.bram18 <= a.res.bram18;
    let strictly_better = b.compute_ps < a.compute_ps
        || b.in_ps < a.in_ps
        || b.out_ps < a.out_ps
        || b.res.luts < a.res.luts
        || b.res.ffs < a.res.ffs
        || b.res.dsps < a.res.dsps
        || b.res.bram18 < a.res.bram18;
    no_worse && strictly_better
}

/// One per-kernel odometer option: an accelerator multiset plus the
/// "+ smp" flag, with the option's total resource footprint precomputed.
struct Opt {
    accels: Vec<(String, u32)>,
    smp: bool,
    res: Resources,
}

/// Per-kernel option lists (pruned and unpruned counterparts share the
/// construction; the unpruned list only feeds the feasible-point counter).
struct OptionTable<'s> {
    kernels: Vec<&'s KernelSpace>,
    /// Options after variant dominance cuts — what actually enumerates.
    pruned: Vec<Vec<Opt>>,
    /// Option *footprints* with every feasible variant kept — used to
    /// count what exhaustive enumeration would produce.
    full_res: Vec<Vec<Resources>>,
    dominated_variants: u64,
    /// Raw cartesian size (counting per-variant infeasible options too).
    space_points: u64,
}

/// Number of variant multisets of size `1..=max_instances` over `v`
/// elements: `Σ_c C(v+c-1, c)` — the raw mixed-variant option count per
/// kernel (the homogeneous count is simply `v × max_instances`).
fn multiset_count(v: u64, max_instances: u32) -> u64 {
    if v == 0 {
        return 0;
    }
    let mut total = 0u64;
    for c in 1..=max_instances as u64 {
        // C(v - 1 + c, c), computed incrementally (each step is integral;
        // saturation only distorts astronomically large stats).
        let mut binom = 1u64;
        for i in 1..=c {
            binom = binom.saturating_mul(v - 1 + i) / i;
        }
        total = total.saturating_add(binom);
    }
    total
}

fn build_options<'s>(ctx: &SweepContext<'_>, space: &'s DseSpace) -> OptionTable<'s> {
    let mut kernels = Vec::new();
    let mut pruned = Vec::new();
    let mut full_res = Vec::new();
    let mut dominated_variants = 0u64;
    let mut space_points = 1u64;
    for ks in &space.kernels {
        let Some(kid) = ctx.program.kernel_id(&ks.kernel) else {
            continue;
        };
        // Raw cartesian: the empty option plus every (variant multiset,
        // smp?) combination, whether or not it fits.
        let raw_opts = if space.mixed {
            multiset_count(ks.unrolls.len() as u64, ks.max_instances)
        } else {
            ks.unrolls.len() as u64 * ks.max_instances as u64
        };
        let smp_modes = if ks.try_smp { 2 } else { 1 };
        space_points = space_points.saturating_mul(1 + raw_opts.saturating_mul(smp_modes));

        // Exhaustive option footprints, duplicates included — exactly the
        // per-kernel options the unpruned odometer (and the exhaustive
        // sweep) would enumerate, used only for the feasible-point count.
        let feas_res: Vec<Resources> = ks
            .unrolls
            .iter()
            .map(|&u| ctx.resources_for(kid, &ks.kernel, u))
            .filter(|r| ctx.part.fits(&[*r]))
            .collect();
        let mut all_res: Vec<Resources> = vec![Resources::ZERO];
        for multiset in super::variant_multisets(feas_res.len(), ks.max_instances, space.mixed) {
            let res = multiset
                .iter()
                .fold(Resources::ZERO, |acc, &vi| acc.add(&feas_res[vi]));
            all_res.push(res);
            if ks.try_smp {
                all_res.push(res);
            }
        }

        // Variants that fit the part at least once, deduplicated: a
        // repeated unroll factor yields byte-identical candidates, so only
        // the first copy enumerates (the dropped copies are counted
        // together with the dominance cut — both are "never worth
        // simulating for the same reason a dominated variant isn't").
        let mut variants: Vec<Variant> = Vec::new();
        for &u in &ks.unrolls {
            if variants.iter().any(|v| v.unroll == u) {
                continue;
            }
            let r = ctx.report_for(kid, &ks.kernel, u);
            if !ctx.part.fits(&[r.resources]) {
                continue;
            }
            variants.push(Variant {
                unroll: u,
                res: r.resources,
                compute_ps: r.compute_ps(),
                in_ps: r.in_ps(),
                out_ps: r.out_ps(),
            });
        }
        let n_before = variants.len();
        let kept: Vec<Variant> = variants
            .iter()
            .filter(|a| !variants.iter().any(|b| dominates(b, a)))
            .cloned()
            .collect();
        dominated_variants += (n_before - kept.len()) as u64;

        // Options via the shared multiset generator — the exact relative
        // order `SweepContext::enumerate` uses (the kept variants are an
        // order-preserving subsequence of the feasible ones), so the
        // surviving candidates keep their enumeration-order tie-break.
        let mut opts: Vec<Opt> = vec![Opt {
            accels: Vec::new(),
            smp: false,
            res: Resources::ZERO,
        }];
        for multiset in super::variant_multisets(kept.len(), ks.max_instances, space.mixed) {
            let res = multiset
                .iter()
                .fold(Resources::ZERO, |acc, &vi| acc.add(&kept[vi].res));
            let accels: Vec<(String, u32)> = multiset
                .iter()
                .map(|&vi| (ks.kernel.clone(), kept[vi].unroll))
                .collect();
            opts.push(Opt {
                accels: accels.clone(),
                smp: false,
                res,
            });
            if ks.try_smp {
                opts.push(Opt {
                    accels,
                    smp: true,
                    res,
                });
            }
        }
        kernels.push(ks);
        pruned.push(opts);
        full_res.push(all_res);
    }
    OptionTable {
        kernels,
        pruned,
        full_res,
        dominated_variants,
        space_points,
    }
}

/// Count the feasible candidates of an option table (what the exhaustive
/// odometer would emit), using the same prefix-sum subtree cut.
fn count_feasible(options: &[Vec<Resources>], budget: &Resources) -> u64 {
    fn rec(options: &[Vec<Resources>], level: usize, total: Resources, budget: &Resources) -> u64 {
        if level == 0 {
            return 1;
        }
        let mut n = 0;
        for res in &options[level - 1] {
            let acc = total.add(res);
            if acc.fits_in(budget) {
                n += rec(options, level - 1, acc, budget);
            }
        }
        n
    }
    if options.is_empty() {
        return 1; // the smp-only candidate
    }
    rec(options, options.len(), Resources::ZERO, budget)
}

/// Pruned odometer: emits, in the exhaustive enumeration order, every
/// feasible candidate built from the dominance-filtered options, skipping
/// whole subtrees whose resource prefix already exceeds the budget.
fn enumerate_options(
    table: &OptionTable<'_>,
    budget: &Resources,
    stats: &mut PruneStats,
) -> Vec<CoDesign> {
    let n = table.pruned.len();
    let mut out = Vec::new();
    if n == 0 {
        let mut cd = CoDesign::new("dse");
        cd.name = describe(&cd);
        out.push(cd);
        return out;
    }
    // Subtree sizes: product of option counts of the levels below.
    let mut below = vec![1u64; n + 1];
    for k in 1..=n {
        below[k] = below[k - 1].saturating_mul(table.pruned[k - 1].len() as u64);
    }
    // Recursion from the last kernel down so kernel 0 varies fastest —
    // the same order as the serial odometer in `SweepContext::enumerate`.
    #[allow(clippy::too_many_arguments)]
    fn rec(
        table: &OptionTable<'_>,
        budget: &Resources,
        level: usize,
        total: Resources,
        picked: &mut Vec<usize>,
        below: &[u64],
        out: &mut Vec<CoDesign>,
        resource_cut: &mut u64,
    ) {
        if level == 0 {
            let mut cd = CoDesign::new("dse");
            for (ki, &oi) in picked.iter().enumerate() {
                let opt = &table.pruned[ki][oi];
                for (k, u) in &opt.accels {
                    cd = cd.with_accel(k, *u);
                }
                if opt.smp {
                    cd = cd.with_smp(&table.kernels[ki].kernel);
                }
            }
            cd.name = describe(&cd);
            out.push(cd);
            return;
        }
        let ki = level - 1;
        for (oi, opt) in table.pruned[ki].iter().enumerate() {
            let acc = total.add(&opt.res);
            if !acc.fits_in(budget) {
                // No completion of this prefix can fit: skip the subtree.
                *resource_cut += below[ki];
                continue;
            }
            picked[ki] = oi;
            rec(table, budget, ki, acc, picked, below, out, resource_cut);
        }
    }
    let mut picked = vec![0usize; n];
    rec(
        table,
        budget,
        n,
        Resources::ZERO,
        &mut picked,
        &below,
        &mut out,
        &mut stats.resource_cut,
    );
    out
}

/// Enumerate the pruned candidate list for a space, with statistics.
///
/// The result is a subset of [`SweepContext::enumerate`] in the same
/// relative order: exactly the feasible candidates that use no dominated
/// unroll variant.
pub fn enumerate_pruned(ctx: &SweepContext<'_>, space: &DseSpace) -> (Vec<CoDesign>, PruneStats) {
    let _t = crate::util::profile::scope("prune");
    let mut stats = PruneStats::default();
    let table = build_options(ctx, space);
    let budget = ctx.part.effective_budget();
    stats.space_points = table.space_points;
    stats.dominated_variants = table.dominated_variants;
    stats.feasible_points = count_feasible(&table.full_res, &budget);
    let cands = enumerate_options(&table, &budget, &mut stats);
    stats.dominance_cut = stats.feasible_points - cands.len() as u64;
    (cands, stats)
}

/// Lower bounds of one candidate in objective space. Both are *valid*
/// lower bounds of the evaluated [`DsePoint`]: `lb_ms <= est_ms` and
/// `lb_energy_j <= energy_j` for the point the simulator would produce.
/// `rank_ms` is a cheap-feature *prediction* (not a bound) used only by
/// [`OrderMode::Ranked`].
#[derive(Clone, Copy, Debug)]
struct CandBound {
    lb_ms: f64,
    lb_energy_j: f64,
    rank_ms: f64,
}

impl CandBound {
    fn score(&self, objective: Objective) -> f64 {
        match objective {
            Objective::Time => self.lb_ms,
            Objective::Energy => self.lb_energy_j,
            Objective::Edp => self.lb_ms * self.lb_energy_j,
        }
    }

    /// Predicted score under the ranked order: the lower-bound score
    /// inflated by the ratio of the predicted to the bounded makespan.
    /// Pure ordering heuristic — never used to cut.
    fn rank_score(&self, objective: Objective) -> f64 {
        self.score(objective) * (self.rank_ms / self.lb_ms.max(f64::MIN_POSITIVE))
    }

    /// Score under an externally supplied predicted makespan (a sibling
    /// board's scaled result) — the warm cross-board ordering prior.
    fn prior_score(&self, objective: Objective, prior_ms: f64) -> f64 {
        self.score(objective) * (prior_ms / self.lb_ms.max(f64::MIN_POSITIVE))
    }
}

/// Compute the makespan and energy lower bounds of a candidate against the
/// shared context. `None` means the co-design cannot run at all (some
/// kernel has no device) — the exhaustive sweep skips those too.
fn bound_for(ctx: &SweepContext<'_>, cd: &CoDesign) -> Option<CandBound> {
    let (accels, smp) = ctx.resolve(cd).ok()?;
    let b = bounds(ctx.program, &ctx.graph, ctx.board, &accels, &smp);
    let lb_ps = b.lower_bound();
    // Energy >= static power over the bounded makespan plus the SMP
    // dynamic power of the (unavoidable, serialized) creation chain. The
    // utilization is computed exactly as `point_from` computes it, so the
    // static-power watts match the evaluated report's bit for bit.
    let resources: Vec<Resources> = accels.iter().map(|a| a.report.resources).collect();
    let util = ctx.part.utilization(&resources);
    let pm = ctx.power_model();
    let static_w = pm.ps_static_w + pm.pl_static_w + pm.pl_static_per_util_w * (util * 100.0);
    let lb_s = lb_ps as f64 / 1e12;
    let creation_s = b.creation_chain as f64 / 1e12;
    let lb_energy = (static_w * lb_s + pm.smp_dynamic_w * creation_s) * ENERGY_LB_MARGIN;
    let lb_ms = ps_to_ms(lb_ps);
    // Cheap-feature makespan prediction for OrderMode::Ranked, from data
    // already in hand. The bound underestimates most when it is
    // device-work-dominated (a low critical-path ratio means the greedy
    // schedule pays dependence stalls the work bound ignores) and when
    // DMA contention is high (proxied by fabric utilization and instance
    // count on the shared output channel). Calibration-free and only ever
    // used to *order* candidates, so a bad prediction costs evaluations,
    // never correctness.
    let cp_ratio = (b.critical_path as f64 / lb_ps.max(1) as f64).clamp(0.0, 1.0);
    let rank_ms =
        lb_ms * (1.0 + 0.35 * (1.0 - cp_ratio) + 0.15 * util + 0.02 * accels.len() as f64);
    Some(CandBound {
        lb_ms,
        lb_energy_j: lb_energy,
        rank_ms,
    })
}

/// Frozen time-energy frontier of the points evaluated in earlier rounds
/// (plus, in warm sweeps, the memo-hit points — flagged `seeded`). A
/// candidate is skippable when some frontier point is *strictly* below
/// both of its lower bounds: the candidate is then strictly dominated in
/// objective space, so it is neither Pareto-optimal nor best under any of
/// the three objectives. Seeded points keep the cut lossless because they
/// are always members of the current sweep's returned point set.
#[derive(Default)]
struct Frontier {
    /// (est_ms, energy_j, seeded-from-warm-state).
    pts: Vec<(f64, f64, bool)>,
}

impl Frontier {
    fn insert(&mut self, ms: f64, energy: f64, seeded: bool) {
        if self
            .pts
            .iter()
            .any(|&(m, e, _)| m <= ms && e <= energy)
        {
            return;
        }
        self.pts.retain(|&(m, e, _)| !(ms <= m && energy <= e));
        self.pts.push((ms, energy, seeded));
    }

    /// `None` when no frontier point strictly dominates the bounds;
    /// `Some(true)` when only *seeded* points do (a cut attributable to
    /// the warm start), `Some(false)` when a point evaluated this run
    /// does.
    fn strictly_dominates(&self, lb: &CandBound) -> Option<bool> {
        let mut seeded_only = None;
        for &(m, e, seeded) in &self.pts {
            if m < lb.lb_ms && e < lb.lb_energy_j {
                if !seeded {
                    return Some(false);
                }
                seeded_only = Some(true);
            }
        }
        seeded_only
    }
}

/// Per-application pruned-exploration state threaded through the rounds.
struct JobState<'a, 'p> {
    ctx: &'a SweepContext<'p>,
    cands: Vec<CoDesign>,
    bounds: Vec<Option<CandBound>>,
    /// Candidate indices in processing order (see [`OrderMode`]).
    order: Vec<usize>,
    cursor: usize,
    frontier: Frontier,
    /// Incumbent-sharing group (cross-board sweeps): jobs with the same
    /// group id also consult — and feed — a shared group frontier. `None`
    /// keeps the job fully self-contained (per-job losslessness).
    group: Option<usize>,
    evaluated: Vec<(usize, PointOutcome)>,
    stats: PruneStats,
    /// Candidates already satisfied from the eval memo (warm sweeps):
    /// excluded from bounds, ordering and evaluation.
    done: Vec<bool>,
    /// Per-candidate predicted-makespan ordering priors (warm cross-board
    /// seeding); `None` falls back to the candidate's own rank features.
    priors: Vec<Option<f64>>,
}

/// Fill `job.order` (and the unrunnable counter) for one job under an
/// [`OrderMode`] — a pure function of the job's candidates, bounds and
/// priors, hence identical for any worker count.
fn build_order(job: &mut JobState<'_, '_>, objective: Objective, mode: OrderMode) {
    let n = job.cands.len();
    let mut order: Vec<usize> = (0..n)
        .filter(|&i| !job.done[i] && job.bounds[i].is_some())
        .collect();
    job.stats.unrunnable = (0..n)
        .filter(|&i| !job.done[i] && job.bounds[i].is_none())
        .count() as u64;
    let bounds = &job.bounds;
    let priors = &job.priors;
    match mode {
        OrderMode::Fifo => {}
        OrderMode::BoundAsc => order.sort_by(|&a, &b| {
            let sa = bounds[a].as_ref().unwrap().score(objective);
            let sb = bounds[b].as_ref().unwrap().score(objective);
            sa.total_cmp(&sb).then(a.cmp(&b))
        }),
        OrderMode::Ranked => {
            job.stats.prior_ordered =
                order.iter().filter(|&&i| priors[i].is_some()).count() as u64;
            order.sort_by(|&a, &b| {
                let key = |i: usize| {
                    let cb = bounds[i].as_ref().unwrap();
                    match priors[i] {
                        Some(prior_ms) => cb.prior_score(objective, prior_ms),
                        None => cb.rank_score(objective),
                    }
                };
                key(a).total_cmp(&key(b)).then(a.cmp(&b))
            });
        }
    }
    job.order = order;
}

/// Evaluate `(job, candidate)` work items on a persistent pool of
/// per-worker, per-job simulators. `slots` outlives the rounds, so each
/// worker's simulator buffers are reused across every round *and* every
/// application — one shared pool for the whole (suite) sweep.
///
/// Every evaluation runs panic-isolated: a panicking candidate poisons
/// only itself — the worker's simulator pool is discarded (a panic can
/// leave a simulator mid-run) and rebuilt lazily, the candidate is
/// recorded as [`PointOutcome::Poisoned`] and the round goes on.
/// `on_round`, when present, is called once per non-empty round with the
/// merged results sorted by `(job, candidate)` index — deterministic for
/// any worker count — after the frontiers thawed; an error from the
/// callback aborts the sweep (the recoverable path surfaces
/// journal-commit failures here).
///
/// `cancel`, when present, is polled at every round **barrier** (before
/// the next round's work list is assembled): a `true` aborts the sweep
/// with [`SweepCancelled`]. The in-flight round always completes first —
/// cancellation can shorten a sweep, never change the bytes of any round
/// that did run.
fn run_rounds<'a, 'p>(
    jobs: &mut [JobState<'a, 'p>],
    workers: usize,
    mut on_round: Option<&mut dyn FnMut(&[(usize, usize, DsePoint)]) -> anyhow::Result<()>>,
    cancel: Option<&(dyn Fn() -> bool + Sync)>,
) -> anyhow::Result<()> {
    // Shared incumbent frontiers of the groups (empty when no job is
    // grouped). Like the per-job frontiers they are only thawed at round
    // barriers, and a frontier's content is the unique Pareto set of the
    // points evaluated so far by its group — independent of the merge
    // order, hence of the worker count.
    let n_groups = jobs
        .iter()
        .filter_map(|j| j.group)
        .max()
        .map_or(0, |g| g + 1);
    let mut group_frontiers: Vec<Frontier> = (0..n_groups).map(|_| Frontier::default()).collect();

    let workers = workers.max(1);
    // One persistent simulator slot per worker per job.
    let mut slots: Vec<Vec<Option<super::sweep::SweepWorker<'a, 'p>>>> = Vec::new();
    for _ in 0..workers {
        slots.push((0..jobs.len()).map(|_| None).collect());
    }

    loop {
        // Deadline/cancellation check at the barrier only: the previous
        // round is fully merged, no evaluation is in flight.
        if cancel.is_some_and(|c| c()) {
            return Err(anyhow::Error::new(SweepCancelled));
        }
        // Assemble this round's work list at the barrier: fixed chunk per
        // job, bound cut against each job's frozen frontier.
        let mut work: Vec<(usize, usize)> = Vec::new();
        for (ji, job) in jobs.iter_mut().enumerate() {
            let end = (job.cursor + ROUND_CHUNK).min(job.order.len());
            for oi in job.cursor..end {
                let ci = job.order[oi];
                // A resumed sweep replays the interrupted run's
                // checkpointed order, in which journal-restored candidates
                // still occupy their original slots: they are done (served
                // as memo hits, no bounds computed), so they consume their
                // position — keeping every round boundary where it was —
                // without being re-evaluated.
                if job.done[ci] {
                    continue;
                }
                let lb = job.bounds[ci].as_ref().unwrap();
                match job.frontier.strictly_dominates(lb) {
                    Some(false) => job.stats.bound_cut += 1,
                    Some(true) => job.stats.seeded_cut += 1,
                    None => {
                        if job.group.is_some_and(|g| {
                            group_frontiers[g].strictly_dominates(lb).is_some()
                        }) {
                            job.stats.global_cut += 1;
                        } else {
                            work.push((ji, ci));
                        }
                    }
                }
            }
            job.cursor = end;
        }
        if work.is_empty() {
            if jobs.iter().all(|j| j.cursor >= j.order.len()) {
                break;
            }
            continue; // a whole round was cut away; advance to the next
        }

        let jobs_ref: &[JobState<'a, 'p>] = &*jobs;
        // Partition the round's work list into neighbor chains (never
        // across jobs): consecutive same-job candidates differing in one
        // kernel's option ride the incremental (delta) path, and the
        // chains — not the points — are the parallel work units, so every
        // delta/scratch decision is a pure function of the work list,
        // identical for any worker count.
        let chains = super::sweep::delta_chains(work.len(), |w| {
            let (ji, ci) = work[w];
            let (pji, pci) = work[w - 1];
            if ji != pji {
                return None;
            }
            super::sweep::single_kernel_diff(
                jobs_ref[ji].ctx.program,
                &jobs_ref[ji].cands[pci],
                &jobs_ref[ji].cands[ci],
            )
        });
        let mut delta: Vec<super::sweep::DeltaStats> =
            vec![Default::default(); jobs_ref.len()];
        let n_slots = slots.len().min(chains.len());
        let outcomes = {
            let _t = crate::util::profile::scope("simulate");
            super::sweep::parallel_for_indexed(&mut slots[..n_slots], chains.len(), |slot, c| {
                let chain = chains[c];
                let ji = work[chain.start].0;
                let out = super::sweep::evaluate_chain(
                    &mut slot[ji],
                    || jobs_ref[ji].ctx.worker(),
                    chain,
                    |w| &jobs_ref[ji].cands[work[w].1],
                );
                Some((ji, out))
            })
        };
        let mut results: Vec<(usize, usize, DsePoint)> = Vec::with_capacity(work.len());
        let mut poisoned: Vec<usize> = Vec::new();
        for (ji, out) in outcomes {
            delta[ji].merge(&out.stats);
            for (w, p) in out.results {
                results.push((ji, work[w].1, p));
            }
            poisoned.extend(out.poisoned);
        }
        poisoned.sort_unstable();
        // Deterministic merge (and journal) order regardless of which
        // thread produced which result.
        results.sort_unstable_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        for (ji, d) in delta.iter().enumerate() {
            jobs[ji].stats.delta_hits += d.hits;
            jobs[ji].stats.delta_fallbacks += d.fallbacks;
            jobs[ji].stats.delta_suffix_events += d.suffix_events;
            jobs[ji].stats.delta_total_events += d.total_events;
        }

        // Barrier: merge results and thaw the frontiers for the next round.
        for &w in &poisoned {
            let (ji, ci) = work[w];
            jobs[ji].stats.poisoned += 1;
            jobs[ji].evaluated.push((ci, PointOutcome::Poisoned));
        }
        for (ji, _, p) in &results {
            jobs[*ji].frontier.insert(p.est_ms, p.energy_j, false);
            if let Some(g) = jobs[*ji].group {
                group_frontiers[g].insert(p.est_ms, p.energy_j, false);
            }
            jobs[*ji].stats.evaluated += 1;
        }
        if let Some(cb) = on_round.as_mut() {
            if !results.is_empty() {
                cb(&results)?;
            }
        }
        for (ji, ci, p) in results {
            jobs[ji].evaluated.push((ci, PointOutcome::Evaluated(p)));
        }
    }
    Ok(())
}

/// Bound-guided pruned exploration over one or more applications sharing
/// one worker pool. Returns, per application, the ranked evaluated points
/// and the cut statistics. See the module docs for the losslessness and
/// determinism guarantees.
pub(crate) fn explore_pruned_multi<'p>(
    inputs: &[(&SweepContext<'p>, &DseSpace)],
    objective: Objective,
    workers: usize,
) -> Vec<(Vec<DsePoint>, PruneStats)> {
    explore_pruned_grouped(inputs, &vec![None; inputs.len()], objective, workers)
}

/// Like [`explore_pruned_multi`], but jobs sharing a `Some(group)` id also
/// share an incumbent frontier: a candidate whose lower bounds are
/// strictly dominated by a point evaluated *anywhere in its group* is
/// skipped. The group-wide best point and the group-wide time-energy
/// Pareto front still equal the exhaustive sweep's (a group-dominated
/// candidate can appear on neither); **per-job** fronts of grouped jobs
/// are no longer guaranteed complete — use `None` groups (the
/// `explore_pruned_multi` path) when per-job losslessness matters.
/// Determinism for any worker count is preserved: group frontiers thaw at
/// the same round barriers as per-job frontiers.
pub(crate) fn explore_pruned_grouped<'p>(
    inputs: &[(&SweepContext<'p>, &DseSpace)],
    groups: &[Option<usize>],
    objective: Objective,
    workers: usize,
) -> Vec<(Vec<DsePoint>, PruneStats)> {
    assert_eq!(inputs.len(), groups.len(), "one group entry per input");
    let mut jobs: Vec<JobState<'_, 'p>> = inputs
        .iter()
        .zip(groups)
        .map(|(&(ctx, space), &group)| {
            let (cands, stats) = enumerate_pruned(ctx, space);
            let n = cands.len();
            JobState {
                ctx,
                cands,
                bounds: Vec::new(),
                order: Vec::new(),
                cursor: 0,
                frontier: Frontier::default(),
                group,
                evaluated: Vec::new(),
                stats,
                done: vec![false; n],
                priors: vec![None; n],
            }
        })
        .collect();

    // Bounds are cheap relative to simulation but not free: compute them
    // in parallel across all jobs, keyed by (job, candidate) index so the
    // result is independent of the worker count.
    let flat: Vec<(usize, usize)> = jobs
        .iter()
        .enumerate()
        .flat_map(|(ji, j)| (0..j.cands.len()).map(move |ci| (ji, ci)))
        .collect();
    let n_workers = workers.clamp(1, flat.len().max(1));
    let computed: Vec<(usize, usize, Option<CandBound>)> = if n_workers <= 1 {
        flat.iter()
            .map(|&(ji, ci)| (ji, ci, bound_for(jobs[ji].ctx, &jobs[ji].cands[ci])))
            .collect()
    } else {
        let jobs_ref: &[JobState<'_, 'p>] = &jobs;
        let mut slots = vec![(); n_workers];
        super::sweep::parallel_for_indexed(&mut slots, flat.len(), |_, w| {
            let (ji, ci) = flat[w];
            Some((ji, ci, bound_for(jobs_ref[ji].ctx, &jobs_ref[ji].cands[ci])))
        })
    };
    for job in jobs.iter_mut() {
        job.bounds = vec![None; job.cands.len()];
    }
    for (ji, ci, b) in computed {
        jobs[ji].bounds[ci] = b;
    }
    for job in jobs.iter_mut() {
        build_order(job, objective, OrderMode::BoundAsc);
    }

    run_rounds(&mut jobs, workers, None, None)
        .expect("a sweep without recovery IO performs no fallible IO");

    jobs.into_iter()
        .map(|mut job| {
            // Enumeration order first, then the same stable score sort as
            // the exhaustive path, so ranking ties break identically.
            // Poisoned candidates are quarantined out of the ranking.
            job.evaluated.sort_unstable_by_key(|e| e.0);
            let mut points: Vec<DsePoint> = job
                .evaluated
                .into_iter()
                .filter_map(|(_, o)| o.into_point())
                .collect();
            points.sort_by(|a, b| a.score(objective).partial_cmp(&b.score(objective)).unwrap());
            (points, job.stats)
        })
        .collect()
}

/// Warm-start / ordered single-job pruned exploration — the engine behind
/// [`SweepContext::explore_warm`] and [`SweepContext::explore_pruned_with`].
/// One-input wrapper over [`explore_pruned_warm_multi`].
pub(crate) fn explore_pruned_warm<'p>(
    ctx: &SweepContext<'p>,
    space: &DseSpace,
    memo: Option<&mut EvalMemo>,
    order: OrderMode,
    objective: Objective,
    workers: usize,
) -> (Vec<DsePoint>, PruneStats) {
    explore_pruned_warm_multi(&[(ctx, space)], memo, order, objective, workers)
        .pop()
        .expect("one input yields one output")
}

/// Warm-start / ordered pruned exploration over one or more jobs sharing
/// **one** worker pool — the engine behind [`SweepContext::explore_warm`],
/// [`SweepSuite::explore_pruned_warm`](super::sweep::SweepSuite) and the
/// warm cross-board sweep. All jobs share the `memo`:
///
/// * **Level 2**: candidates whose exact `(context, co-design)` evaluation
///   is recorded are returned without re-simulation
///   ([`PruneStats::memo_hits`]) and pre-seed the job's bound frontier — a
///   warm incumbent. Seeded frontier points are always members of *that*
///   job's returned set, so the cut stays lossless. Newly evaluated points
///   are recorded back.
/// * **Level 1**: under [`OrderMode::Ranked`], candidates draw ordering
///   priors from the memo's per-kernel occupancy statistics
///   ([`EvalMemo::prior_ms_for`]) — cross-size and sibling-board
///   predictions, counted in [`PruneStats::prior_ordered`]. Ordering only:
///   candidates are still cut exclusively by their own real bounds against
///   really evaluated (or memo-exact) points, so results stay exact. After
///   the sweep each job's kernel variants and fresh occupancy samples are
///   recorded back ([`EvalMemo::record_kernels`] /
///   [`EvalMemo::record_occupancy`]), and level-1 cache-prime hits are
///   surfaced as [`PruneStats::kernel_hits`].
///
/// Guarantees, as everywhere in this module: per job, best point and
/// time-energy Pareto front equal the exhaustive sweep's; output and stats
/// are bit-identical for any worker count (level-1 statistics use
/// order-independent aggregation, so the saved memo is too).
pub(crate) fn explore_pruned_warm_multi<'p>(
    inputs: &[(&SweepContext<'p>, &DseSpace)],
    memo: Option<&mut EvalMemo>,
    order: OrderMode,
    objective: Objective,
    workers: usize,
) -> Vec<(Vec<DsePoint>, PruneStats)> {
    explore_pruned_warm_driver(inputs, memo, order, objective, workers, None, None)
        .expect("a warm sweep without recovery IO performs no fallible IO")
}

/// Single-job warm exploration with a cooperative cancellation hook —
/// the engine behind [`SweepContext::explore_warm_cancellable`] and the
/// service daemon's per-request deadlines. `cancel` is polled at round
/// barriers only (see [`run_rounds`]); a cancelled sweep returns
/// [`SweepCancelled`] (downcastable) and leaves the memo **unmodified** —
/// recording happens strictly after a sweep completes.
pub(crate) fn explore_pruned_warm_cancellable<'p>(
    ctx: &SweepContext<'p>,
    space: &DseSpace,
    memo: Option<&mut EvalMemo>,
    order: OrderMode,
    objective: Objective,
    workers: usize,
    cancel: Option<&(dyn Fn() -> bool + Sync)>,
) -> anyhow::Result<(Vec<DsePoint>, PruneStats)> {
    let mut out = explore_pruned_warm_driver(
        &[(ctx, space)],
        memo,
        order,
        objective,
        workers,
        None,
        cancel,
    )?;
    Ok(out.pop().expect("one input yields one output"))
}

/// [`explore_pruned_warm_multi`] with crash recovery: given a
/// [`RecoverySession`], the sweep journals every committed round of fresh
/// evaluations to the memo's `.wal` sidecar (one fsync per round) and
/// checkpoints the per-job candidate orders to the `.ckpt` sidecar before
/// the first round. On resume — after
/// [`EvalMemo::load_with_recovery`](super::warm::EvalMemo::load_with_recovery)
/// replayed the journal into the memo — the restored state is folded back
/// so the finished ranking and the subsequently saved memo are
/// **bit-identical** to an uninterrupted run: journal-restored points
/// re-enter the occupancy recording as the fresh evaluations they were,
/// their contexts skip the per-sweep `touch` (the journal already restored
/// that recency), and the checkpointed order — not a freshly built one —
/// fixes the round boundaries. Only the cut *attribution* may differ
/// (restored points count as `memo_hits`/`seeded_cut` rather than
/// `evaluated`/`bound_cut`); the returned point sets do not.
pub(crate) fn explore_pruned_warm_recoverable<'p>(
    inputs: &[(&SweepContext<'p>, &DseSpace)],
    memo: Option<&mut EvalMemo>,
    order: OrderMode,
    objective: Objective,
    workers: usize,
    recovery: Option<&mut RecoverySession>,
) -> anyhow::Result<Vec<(Vec<DsePoint>, PruneStats)>> {
    explore_pruned_warm_driver(inputs, memo, order, objective, workers, recovery, None)
}

/// The shared driver behind the warm exploration entry points, adding the
/// round-barrier `cancel` hook to the recoverable path's journaling.
#[allow(clippy::too_many_arguments)]
fn explore_pruned_warm_driver<'p>(
    inputs: &[(&SweepContext<'p>, &DseSpace)],
    mut memo: Option<&mut EvalMemo>,
    order: OrderMode,
    objective: Objective,
    workers: usize,
    mut recovery: Option<&mut RecoverySession>,
    cancel: Option<&(dyn Fn() -> bool + Sync)>,
) -> anyhow::Result<Vec<(Vec<DsePoint>, PruneStats)>> {
    // A deadline that already expired must leave the memo byte-identical:
    // the per-sweep `touch` below bumps the persisted recency clock, so
    // the first barrier check happens *before* job setup.
    if cancel.is_some_and(|c| c()) {
        return Err(anyhow::Error::new(SweepCancelled));
    }
    // Recovery journals and restores *memo* state; without a memo there is
    // nothing to persist or resume.
    if memo.is_none() {
        recovery = None;
    }
    let mut jobs: Vec<JobState<'_, 'p>> = Vec::new();
    let mut fps: Vec<u64> = Vec::new();
    let mut keys_per_job: Vec<Vec<String>> = Vec::new();
    let mut hits_per_job: Vec<Vec<(usize, DsePoint)>> = Vec::new();
    let mut wal_hits_per_job: Vec<Vec<DsePoint>> = Vec::new();
    for &(ctx, space) in inputs {
        let (cands, mut stats) = enumerate_pruned(ctx, space);
        stats.kernel_hits = ctx.kernel_memo_hits() as u64;
        let n = cands.len();
        let keys: Vec<String> = cands.iter().map(super::warm::codesign_key).collect();
        let fp = super::warm::context_fingerprint(ctx);
        let mut job = JobState {
            ctx,
            cands,
            bounds: Vec::new(),
            order: Vec::new(),
            cursor: 0,
            frontier: Frontier::default(),
            group: None,
            evaluated: Vec::new(),
            stats,
            done: vec![false; n],
            priors: vec![None; n],
        };
        // Memo hits: serve them up front (enumeration order —
        // deterministic) and seed the frontier so round 0 already cuts
        // against a warm incumbent.
        let mut hits: Vec<(usize, DsePoint)> = Vec::new();
        let mut wal_hits: Vec<DsePoint> = Vec::new();
        let restored_ctx = recovery
            .as_deref()
            .is_some_and(|r| r.recovered().contexts.contains(&fp));
        if let Some(m) = memo.as_deref_mut() {
            // A context restored by the journal replay already carries the
            // interrupted sweep's per-sweep touch in its restored recency
            // and clock; touching again would diverge the saved memo from
            // the uninterrupted run's.
            if !restored_ctx {
                let recency = m.touch(fp);
                if let Some(r) = recovery.as_deref_mut() {
                    r.journal().log_context(fp, ctx, recency);
                }
            }
            for (i, key) in keys.iter().enumerate() {
                if let Some(v) = m.lookup(fp, key) {
                    job.done[i] = true;
                    job.stats.memo_hits += 1;
                    job.frontier.insert(v.est_ms, v.energy_j, true);
                    let p = DsePoint {
                        codesign: job.cands[i].clone(),
                        est_ms: v.est_ms,
                        energy_j: v.energy_j,
                        edp: v.edp,
                        fabric_util: v.fabric_util,
                    };
                    // Hits restored from the journal were *fresh*
                    // evaluations of the interrupted sweep — remembered so
                    // the occupancy recording below folds them in exactly
                    // like the uninterrupted run would have.
                    if recovery
                        .as_deref()
                        .is_some_and(|r| r.recovered().contains(fp, key))
                    {
                        wal_hits.push(p.clone());
                    }
                    hits.push((i, p));
                }
            }
        }
        // Level-1 ordering priors for the misses (Ranked order only — the
        // other modes never read them).
        if order == OrderMode::Ranked {
            if let Some(m) = memo.as_deref() {
                let counts = super::warm::kernel_task_counts(job.ctx.program);
                for i in 0..n {
                    if !job.done[i] {
                        job.priors[i] = m.prior_ms_for(job.ctx, &counts, &job.cands[i]);
                    }
                }
            }
        }
        fps.push(fp);
        keys_per_job.push(keys);
        hits_per_job.push(hits);
        wal_hits_per_job.push(wal_hits);
        jobs.push(job);
    }

    // Bounds for the remaining candidates across all jobs, keyed by
    // (job, candidate) index so the result is independent of the worker
    // count.
    let mut flat: Vec<(usize, usize)> = Vec::new();
    for (ji, job) in jobs.iter().enumerate() {
        for (ci, &served) in job.done.iter().enumerate() {
            if !served {
                flat.push((ji, ci));
            }
        }
    }
    let n_workers = workers.clamp(1, flat.len().max(1));
    let computed: Vec<(usize, usize, Option<CandBound>)> = if n_workers <= 1 {
        flat.iter()
            .map(|&(ji, ci)| (ji, ci, bound_for(jobs[ji].ctx, &jobs[ji].cands[ci])))
            .collect()
    } else {
        let jobs_ref: &[JobState<'_, 'p>] = &jobs;
        let mut slots = vec![(); n_workers];
        super::sweep::parallel_for_indexed(&mut slots, flat.len(), |_, w| {
            let (ji, ci) = flat[w];
            Some((ji, ci, bound_for(jobs_ref[ji].ctx, &jobs_ref[ji].cands[ci])))
        })
    };
    for job in jobs.iter_mut() {
        job.bounds = vec![None; job.cands.len()];
    }
    for (ji, ci, b) in computed {
        jobs[ji].bounds[ci] = b;
    }
    for job in jobs.iter_mut() {
        build_order(job, objective, order);
    }

    if let Some(r) = recovery.as_deref_mut() {
        // Pin the round boundaries across interruptions: a resumed run
        // replays the checkpointed candidate order of the interrupted one
        // (a freshly built order would exclude the journal-restored hits
        // and shift every round boundary — and with it which candidates
        // the frozen-frontier bound cut skips), and a fresh run
        // checkpoints its orders before the first round.
        let sfps: Vec<u64> = (0..jobs.len())
            .map(|ji| super::ckpt::space_fingerprint(fps[ji], inputs[ji].1, objective, order))
            .collect();
        for (ji, job) in jobs.iter_mut().enumerate() {
            if let Some(saved) = r.checkpoint_order(ji, sfps[ji]) {
                job.order = saved.to_vec();
            }
        }
        let orders: Vec<(u64, &[usize])> = sfps
            .iter()
            .zip(jobs.iter())
            .map(|(&sfp, j)| (sfp, j.order.as_slice()))
            .collect();
        r.save_orders(&orders)?;
    }

    // Journal each committed round: every fresh point of the round plus a
    // commit marker reach disk in one fsynced append, so a crash loses at
    // most the in-flight round. The `sweep.round` faultpoint sits *after*
    // the commit — the recovery tests interrupt sweeps at a point where
    // the round is already durable.
    let mut journal_round = |round: &[(usize, usize, DsePoint)]| -> anyhow::Result<()> {
        if let Some(r) = recovery.as_deref_mut() {
            for (ji, ci, p) in round {
                r.journal().log_point(fps[*ji], &keys_per_job[*ji][*ci], p);
            }
            r.journal().commit_round()?;
            crate::util::faultpoint::hit("sweep.round")?;
        }
        Ok(())
    };
    run_rounds(&mut jobs, workers, Some(&mut journal_round), cancel)?;

    // Record the fresh evaluations (both levels) for the next sweep.
    // Poisoned candidates are quarantined: never recorded, never ranked.
    if let Some(m) = memo.as_deref_mut() {
        for (ji, job) in jobs.iter().enumerate() {
            m.record_kernels(job.ctx, inputs[ji].1);
            for (ci, outcome) in &job.evaluated {
                if let Some(p) = outcome.point() {
                    m.record(job.ctx, fps[ji], &keys_per_job[ji][*ci], p);
                }
            }
            // Journal-restored hits were fresh evaluations of the
            // interrupted run: fold them back into the occupancy
            // statistics so the saved memo matches an uninterrupted run's
            // bit for bit (the aggregation is order-independent).
            let mut fresh: Vec<DsePoint> = job
                .evaluated
                .iter()
                .filter_map(|(_, o)| o.point().cloned())
                .collect();
            fresh.append(&mut wal_hits_per_job[ji]);
            m.record_occupancy(job.ctx, &fresh);
        }
    }

    // Merge hits + evaluations in enumeration order, then the same stable
    // score sort as everywhere else.
    Ok(jobs
        .into_iter()
        .zip(hits_per_job)
        .map(|(job, hits)| {
            let mut all = hits;
            all.extend(
                job.evaluated
                    .into_iter()
                    .filter_map(|(ci, o)| o.into_point().map(|p| (ci, p))),
            );
            all.sort_unstable_by_key(|e| e.0);
            let mut points: Vec<DsePoint> = all.into_iter().map(|(_, p)| p).collect();
            points.sort_by(|a, b| a.score(objective).partial_cmp(&b.score(objective)).unwrap());
            (points, job.stats)
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::cholesky::Cholesky;
    use crate::apps::matmul::Matmul;
    use crate::config::BoardConfig;
    use crate::coordinator::task::{Dep, KernelDecl, KernelProfile, TaskProgram, Targets};
    use crate::hls::FpgaPart;

    use super::super::pareto_front_coords as front_coords;

    fn assert_lossless(
        ctx: &SweepContext<'_>,
        space: &DseSpace,
        objective: Objective,
    ) -> PruneStats {
        let exhaustive = ctx.explore(space, objective, 2);
        let (pruned, stats) = ctx.explore_pruned(space, objective, 2);
        assert_eq!(
            stats.evaluated as usize,
            pruned.len(),
            "stats/result length mismatch"
        );
        assert!(!exhaustive.is_empty());
        assert_eq!(
            exhaustive[0].score(objective).to_bits(),
            pruned[0].score(objective).to_bits(),
            "best point diverged: {} vs {}",
            exhaustive[0].codesign.name,
            pruned[0].codesign.name
        );
        assert_eq!(
            front_coords(&exhaustive),
            front_coords(&pruned),
            "Pareto front diverged"
        );
        assert_eq!(stats.feasible_points as usize, ctx.enumerate(space).len());
        stats
    }

    #[test]
    fn pruned_enumeration_matches_exhaustive_without_dominance() {
        // Default matmul space: no variant is dominated, so the pruned
        // candidate list must be exactly the exhaustive one, in order.
        let board = BoardConfig::zynq706();
        let p = Matmul::new(512, 64).build_program(&board);
        let space = DseSpace::from_program(&p);
        let ctx = SweepContext::for_space(&p, &board, &FpgaPart::xc7z045(), &space);
        let (pruned, stats) = enumerate_pruned(&ctx, &space);
        let exhaustive = ctx.enumerate(&space);
        assert_eq!(stats.dominance_cut, 0);
        assert_eq!(pruned.len(), exhaustive.len());
        for (a, b) in pruned.iter().zip(&exhaustive) {
            assert_eq!(a, b);
        }
        assert_eq!(stats.feasible_points, exhaustive.len() as u64);
        assert!(stats.space_points >= stats.feasible_points);
    }

    /// A kernel whose inner loop saturates at small unrolls: beyond the
    /// trip count, extra unroll only deepens the pipeline (more cycles)
    /// and burns more area — the textbook dominated variant.
    fn tiny_trip_program() -> TaskProgram {
        let mut p = TaskProgram::new("tiny");
        let k = p.add_kernel(KernelDecl {
            name: "tk".into(),
            targets: Targets::FPGA,
            profile: KernelProfile {
                flops: 200,
                inner_trip: 100,
                in_bytes: 8_192,
                out_bytes: 4_096,
                dtype_bytes: 4,
                divsqrt: false,
            },
        });
        for i in 0..12u64 {
            p.add_task(k, 10_000, vec![Dep::inout(0x1000 + i * 0x100, 4_096)]);
        }
        p
    }

    #[test]
    fn dominance_cut_drops_saturated_unrolls() {
        let board = BoardConfig::zynq706();
        let p = tiny_trip_program();
        let space = DseSpace {
            kernels: vec![KernelSpace {
                kernel: "tk".into(),
                unrolls: vec![64, 128],
                max_instances: 2,
                try_smp: false,
            }],
            mixed: false,
        };
        let ctx = SweepContext::for_space(&p, &board, &FpgaPart::xc7z045(), &space);
        // Past saturation (trip = 100): U128 takes ceil(100/128) = 1
        // iteration but a deeper pipeline than U64's 2 iterations, so it
        // has strictly more cycles AND strictly more resources while still
        // fitting the part — strictly worse in both, it never enumerates.
        let (cands, stats) = enumerate_pruned(&ctx, &space);
        assert_eq!(stats.dominated_variants, 1, "{stats:?}");
        assert!(stats.dominance_cut > 0, "{stats:?}");
        assert!(cands
            .iter()
            .all(|c| c.accels.iter().all(|a| a.unroll == 64)));
        // And the cut is lossless.
        let st = assert_lossless(&ctx, &space, Objective::Time);
        assert!(
            st.evaluated < st.feasible_points,
            "pruning must evaluate strictly fewer points: {st:?}"
        );
    }

    #[test]
    fn subtree_resource_cut_counts_cartesian_holes() {
        // Cholesky space: many cross-kernel combinations blow the DSP
        // budget; the prefix cut must skip them without materializing.
        let board = BoardConfig::zynq706();
        let p = Cholesky::new(256, 64).build_program(&board);
        let space = DseSpace::from_program(&p);
        let ctx = SweepContext::for_space(&p, &board, &FpgaPart::xc7z045(), &space);
        let (cands, stats) = enumerate_pruned(&ctx, &space);
        assert!(stats.resource_cut > 0, "{stats:?}");
        assert_eq!(stats.feasible_points as usize, ctx.enumerate(&space).len());
        // No dominance in the default space: candidate sets must agree.
        assert_eq!(cands.len(), ctx.enumerate(&space).len());
    }

    #[test]
    fn bound_cut_fires_and_is_lossless_on_cholesky() {
        let board = BoardConfig::zynq706();
        let p = Cholesky::new(256, 64).build_program(&board);
        let space = DseSpace::from_program(&p);
        let ctx = SweepContext::for_space(&p, &board, &FpgaPart::xc7z045(), &space);
        for objective in [Objective::Time, Objective::Edp] {
            let stats = assert_lossless(&ctx, &space, objective);
            assert!(stats.bound_cut > 0, "no bound cuts fired: {stats:?}");
            assert!(
                stats.evaluated < stats.feasible_points,
                "pruning must evaluate strictly fewer points: {stats:?}"
            );
        }
    }

    #[test]
    fn pruned_explore_is_deterministic_across_worker_counts() {
        let board = BoardConfig::zynq706();
        let p = Matmul::new(512, 64).build_program(&board);
        let space = DseSpace::from_program(&p);
        let ctx = SweepContext::for_space(&p, &board, &FpgaPart::xc7z045(), &space);
        let (base, base_stats) = ctx.explore_pruned(&space, Objective::Time, 1);
        for workers in [2, 4, 8] {
            let (pts, stats) = ctx.explore_pruned(&space, Objective::Time, workers);
            assert_eq!(stats, base_stats, "workers={workers}");
            assert_eq!(pts.len(), base.len(), "workers={workers}");
            for (a, b) in pts.iter().zip(&base) {
                assert_eq!(a.codesign.name, b.codesign.name, "workers={workers}");
                assert_eq!(a.est_ms.to_bits(), b.est_ms.to_bits(), "workers={workers}");
                assert_eq!(
                    a.energy_j.to_bits(),
                    b.energy_j.to_bits(),
                    "workers={workers}"
                );
            }
        }
    }

    #[test]
    fn cancellation_aborts_at_the_barrier_and_leaves_the_memo_untouched() {
        let board = BoardConfig::zynq706();
        let p = Matmul::new(512, 64).build_program(&board);
        let space = DseSpace::from_program(&p);
        let ctx = SweepContext::for_space(&p, &board, &FpgaPart::xc7z045(), &space);
        // Cancel immediately: the very first barrier check fires, no round
        // runs, the memo records nothing.
        let mut memo = EvalMemo::new();
        let before = memo.to_json();
        let err = explore_pruned_warm_cancellable(
            &ctx,
            &space,
            Some(&mut memo),
            OrderMode::BoundAsc,
            Objective::Time,
            2,
            Some(&(|| true)),
        )
        .unwrap_err();
        assert!(
            err.downcast_ref::<SweepCancelled>().is_some(),
            "cancellation must surface as SweepCancelled: {err:#}"
        );
        assert_eq!(memo.to_json(), before, "cancelled sweep touched the memo");
        // A hook that never fires is byte-identical to the plain warm path.
        let (cancellable, _) = explore_pruned_warm_cancellable(
            &ctx,
            &space,
            Some(&mut memo),
            OrderMode::BoundAsc,
            Objective::Time,
            2,
            Some(&(|| false)),
        )
        .unwrap();
        let mut memo2 = EvalMemo::new();
        let (plain, _) = explore_pruned_warm(
            &ctx,
            &space,
            Some(&mut memo2),
            OrderMode::BoundAsc,
            Objective::Time,
            2,
        );
        assert_eq!(cancellable.len(), plain.len());
        for (a, b) in cancellable.iter().zip(&plain) {
            assert_eq!(a.codesign.name, b.codesign.name);
            assert_eq!(a.est_ms.to_bits(), b.est_ms.to_bits());
        }
        assert_eq!(memo.to_json(), memo2.to_json());
    }

    #[test]
    fn bounds_are_valid_lower_bounds() {
        // For every evaluated candidate of the matmul space, the bound
        // used for cutting must sit at or below the evaluated point.
        let board = BoardConfig::zynq706();
        let p = Matmul::new(512, 64).build_program(&board);
        let space = DseSpace::from_program(&p);
        let ctx = SweepContext::for_space(&p, &board, &FpgaPart::xc7z045(), &space);
        let mut w = ctx.worker();
        for cd in ctx.enumerate(&space) {
            let Some(lb) = bound_for(&ctx, &cd) else {
                continue;
            };
            let Some(p) = w.evaluate(&cd) else {
                panic!("bound exists but evaluation skipped for {}", cd.name);
            };
            assert!(
                lb.lb_ms <= p.est_ms,
                "{}: time bound {} > est {}",
                cd.name,
                lb.lb_ms,
                p.est_ms
            );
            assert!(
                lb.lb_energy_j <= p.energy_j,
                "{}: energy bound {} > energy {}",
                cd.name,
                lb.lb_energy_j,
                p.energy_j
            );
        }
    }
}
