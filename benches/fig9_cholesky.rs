//! Fig. 9 regeneration: tiled cholesky, estimator vs board emulator across
//! the six resource-distribution co-designs (FR-dgemm / FR-dsyrk /
//! FR-dtrsm and the three dgemm pairs), normalized to the slowest.
//!
//! Paper shape to hold: estimator and real execution pick the same best
//! configuration; trends agree; the two-accelerator dgemm mixes beat the
//! single full-resources variants.

use zynq_estimator::config::BoardConfig;
use zynq_estimator::experiments;
use zynq_estimator::util::bench::bench;

fn main() {
    let board = BoardConfig::zynq706();
    let table = experiments::fig9(512, &board, experiments::BOARD_REPS).unwrap();
    println!(
        "{}",
        table.render("Fig. 9: cholesky 512x512 (BS=64 dp) — estimator vs board emulator")
    );

    bench("fig9 full sweep (6 configs, est+10x board)", 1, 5, || {
        experiments::fig9(512, &board, experiments::BOARD_REPS).unwrap();
    });
    bench("fig9 estimator only (6 configs)", 1, 10, || {
        let app = zynq_estimator::apps::cholesky::Cholesky::new(512, 64);
        let p = app.build_program(&board);
        for cd in zynq_estimator::apps::cholesky::fig9_codesigns() {
            zynq_estimator::sim::estimate(&p, &cd, &board).unwrap();
        }
    });
}
