//! Deterministic in-process fuzzing of the byte-ingesting parsers.
//!
//! The estimator swallows four kinds of external bytes: memo JSON
//! documents ([`EvalMemo::from_json`]), sweep journals
//! ([`EvalMemo::replay_wal_text`]), board TOML files
//! ([`BoardConfig::from_toml`]) and the service daemon's NDJSON request
//! envelopes ([`parse_request`], including nested `batch` items). Each
//! must *reject* hostile input with an error — never panic, hang or
//! accept garbage silently — because a corrupt file is quarantined and
//! the sweep continues (and a daemon answers every malformed line with
//! a structured error); a panic would abort the process.
//!
//! The build is fully offline with no nightly toolchain, so instead of
//! `cargo-fuzz`/libFuzzer this is a seeded mutation fuzzer on the repo's
//! own PRNG: every case derives from `(seed, case index)` alone, so a
//! failure reported by `zynq-estimator fuzz` reproduces bit-for-bit with
//! the same `--seed`/`--iters`. Seeds come from built-in format-true
//! documents plus the committed corpus under `rust/fuzz/corpus/`.
//!
//! A *pass* is "accepted or rejected with an `Err`"; the only failure
//! mode is a panic, surfaced with the reproducing case index.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;

use crate::config::BoardConfig;
use crate::dse::EvalMemo;
use crate::service::parse_request;
use crate::util::Rng;

/// Which byte-ingesting parser to fuzz.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FuzzTarget {
    /// [`EvalMemo::from_json`] — the persistent memo document.
    MemoJson,
    /// [`EvalMemo::replay_wal_text`] — the `<memo>.wal` journal.
    WalReplay,
    /// [`BoardConfig::from_toml`] — board description files.
    BoardToml,
    /// [`parse_request`] — the service daemon's NDJSON wire envelopes
    /// (every request shape, including nested `batch` items). Each line
    /// of the input document is parsed independently, exactly as the
    /// daemon's read loop would feed it.
    Proto,
}

impl FuzzTarget {
    /// Every target, in a stable order.
    pub const ALL: [FuzzTarget; 4] = [
        FuzzTarget::MemoJson,
        FuzzTarget::WalReplay,
        FuzzTarget::BoardToml,
        FuzzTarget::Proto,
    ];

    /// Parse a CLI/corpus-directory name.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "memo-json" => Some(FuzzTarget::MemoJson),
            "wal-replay" => Some(FuzzTarget::WalReplay),
            "board-toml" => Some(FuzzTarget::BoardToml),
            "proto-ndjson" => Some(FuzzTarget::Proto),
            _ => None,
        }
    }

    /// The CLI name; also the corpus subdirectory under
    /// `rust/fuzz/corpus/`.
    pub fn name(self) -> &'static str {
        match self {
            FuzzTarget::MemoJson => "memo-json",
            FuzzTarget::WalReplay => "wal-replay",
            FuzzTarget::BoardToml => "board-toml",
            FuzzTarget::Proto => "proto-ndjson",
        }
    }
}

/// Outcome of one [`run_target`] campaign.
#[derive(Clone, Debug)]
pub struct FuzzReport {
    /// Target name ([`FuzzTarget::name`]).
    pub target: &'static str,
    /// Base seed of the campaign (reproduces it).
    pub seed: u64,
    /// Mutated inputs exercised.
    pub cases: u64,
    /// Inputs the parser accepted.
    pub accepted: u64,
    /// Inputs the parser rejected with an error (a pass, not a failure).
    pub rejected: u64,
    /// Panics, one line each with the reproducing case index.
    pub failures: Vec<String>,
}

impl FuzzReport {
    /// One-line summary plus one line per failure.
    pub fn render(&self) -> String {
        let mut out = format!(
            "fuzz {}: {} cases (seed {:#x}): {} accepted, {} rejected, {} panic(s)\n",
            self.target,
            self.cases,
            self.seed,
            self.accepted,
            self.rejected,
            self.failures.len(),
        );
        for f in &self.failures {
            out.push_str("  FAIL ");
            out.push_str(f);
            out.push('\n');
        }
        out
    }
}

/// Built-in format-true seed documents for a target — always available,
/// so `fuzz` runs without a corpus checkout too.
pub fn builtin_seeds(target: FuzzTarget) -> Vec<Vec<u8>> {
    match target {
        FuzzTarget::MemoJson => vec![EvalMemo::new().to_json().into_bytes()],
        FuzzTarget::WalReplay => {
            let fabric = 125.0f64.to_bits();
            let ms = 1.25f64.to_bits();
            let ej = 0.5f64.to_bits();
            let edp = 0.000625f64.to_bits();
            let fu = 0.3f64.to_bits();
            let doc = format!(
                "{{\"t\":\"hdr\",\"version\":{},\"estimator\":\"{}\"}}\n\
                 {{\"t\":\"ctx\",\"fp\":\"00000000deadbeef\",\"app\":\"matmul\",\
                 \"board\":\"zynq706\",\"part\":\"xc7z045\",\"fabric_mhz\":{fabric},\
                 \"n_tasks\":99,\"last_used\":3}}\n\
                 {{\"t\":\"pt\",\"fp\":\"00000000deadbeef\",\"key\":\"mxm64:U32\",\
                 \"est_ms\":{ms},\"energy_j\":{ej},\"edp\":{edp},\"fabric_util\":{fu}}}\n\
                 {{\"t\":\"commit\",\"round\":1}}\n",
                crate::dse::warm::MEMO_SCHEMA_VERSION,
                env!("CARGO_PKG_VERSION"),
            );
            vec![doc.into_bytes()]
        }
        FuzzTarget::BoardToml => vec![
            BoardConfig::zynq706().to_toml().into_bytes(),
            BoardConfig::zynq_ultrascale().to_toml().into_bytes(),
        ],
        FuzzTarget::Proto => {
            // One format-true line per request shape (the daemon's read
            // loop feeds lines independently, so a multi-line document
            // seeds every shape at once).
            let doc = concat!(
                r#"{"id":1,"req":"estimate","app":"matmul","n":256,"bs":64,"accel":["mxm64:U32"],"smp":[]}"#,
                "\n",
                r#"{"id":2,"req":"energy","app":"lu","n":256,"bs":64,"accel":["trsm_row:U16"]}"#,
                "\n",
                r#"{"id":3,"req":"dse","app":"matmul","n":128,"objective":"time","top":5,"mixed":false,"order":"ranked"}"#,
                "\n",
                r#"{"id":4,"req":"memo","action":"stats"}"#,
                "\n",
                r#"{"id":5,"req":"memo","action":"gc","max_bytes":65536,"app_floor":1}"#,
                "\n",
                r#"{"id":6,"req":"ping"}"#,
                "\n",
                r#"{"id":7,"req":"health"}"#,
                "\n",
                r#"{"id":8,"req":"estimate","app":"matmul","accel":["mxm64:U32"],"deadline_ms":250}"#,
                "\n",
                r#"{"id":9,"req":"batch","items":[{"id":"a","req":"estimate","app":"matmul","accel":["mxm64:U32"]},{"id":"b","req":"energy","app":"lu","accel":["trsm_row:U16"]}]}"#,
                "\n",
                r#"{"id":10,"req":"shutdown"}"#,
                "\n",
            );
            vec![doc.as_bytes().to_vec()]
        }
    }
}

/// Load every file of a corpus directory (sorted by name, for
/// deterministic seed selection). A missing directory is an error — the
/// caller asked for a corpus that is not there.
pub fn load_corpus(dir: &Path) -> anyhow::Result<Vec<Vec<u8>>> {
    let mut names: Vec<std::path::PathBuf> = std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("{}: {e}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.is_file())
        .collect();
    names.sort();
    let mut out = Vec::new();
    for p in names {
        out.push(std::fs::read(&p).map_err(|e| anyhow::anyhow!("{}: {e}", p.display()))?);
    }
    Ok(out)
}

/// Structural tokens spliced into inputs — the shapes that historically
/// break hand-rolled parsers (unbalanced brackets, huge or non-finite
/// numbers, embedded quotes and NULs).
const TOKENS: [&[u8]; 14] = [
    b"{", b"}", b"[", b"]", b"\"", b"\\", b",", b"\n", b"\0", b"null", b"-1",
    b"1e308", b"nan", b"9223372036854775807",
];

/// Mutate one seed document: 1-4 operations drawn from byte-flip,
/// truncate, insert, chunk-duplicate and token-splice.
fn mutate(base: &[u8], rng: &mut Rng) -> Vec<u8> {
    let mut b = base.to_vec();
    let ops = 1 + rng.next_u64() % 4;
    for _ in 0..ops {
        match rng.next_u64() % 5 {
            0 if !b.is_empty() => {
                let i = (rng.next_u64() % b.len() as u64) as usize;
                b[i] ^= (rng.next_u64() & 0xFF) as u8;
            }
            1 if !b.is_empty() => {
                let i = (rng.next_u64() % b.len() as u64) as usize;
                b.truncate(i);
            }
            2 => {
                let i = (rng.next_u64() % (b.len() as u64 + 1)) as usize;
                b.insert(i, (rng.next_u64() & 0xFF) as u8);
            }
            3 if b.len() >= 2 => {
                let start = (rng.next_u64() % b.len() as u64) as usize;
                let max_len = (b.len() - start).min(32);
                let len = 1 + (rng.next_u64() % max_len as u64) as usize;
                let chunk: Vec<u8> = b[start..start + len].to_vec();
                let at = (rng.next_u64() % (b.len() as u64 + 1)) as usize;
                b.splice(at..at, chunk);
            }
            _ => {
                let tok = TOKENS[(rng.next_u64() % TOKENS.len() as u64) as usize];
                let at = (rng.next_u64() % (b.len() as u64 + 1)) as usize;
                b.splice(at..at, tok.iter().copied());
            }
        }
    }
    b
}

fn exercise(target: FuzzTarget, text: &str) -> bool {
    match target {
        FuzzTarget::MemoJson => EvalMemo::from_json(text).is_ok(),
        FuzzTarget::WalReplay => EvalMemo::new().replay_wal_text(text).is_ok(),
        FuzzTarget::BoardToml => BoardConfig::from_toml(text).is_ok(),
        FuzzTarget::Proto => {
            // Line-at-a-time, like the daemon; "accepted" means every
            // non-blank line parsed. Either way each line must yield a
            // typed envelope or a structured error — never a panic.
            let mut all_ok = true;
            for line in text.lines().filter(|l| !l.trim().is_empty()) {
                all_ok &= parse_request(line).is_ok();
            }
            all_ok
        }
    }
}

/// Run one fuzz campaign: `iters` mutated inputs derived from
/// `(seed, case index)`, over the built-in seeds plus `corpus_dir` (when
/// given, its `<target-name>/` subdirectory must exist). Deterministic:
/// the same arguments produce the same report.
pub fn run_target(
    target: FuzzTarget,
    corpus_dir: Option<&Path>,
    iters: u64,
    seed: u64,
) -> anyhow::Result<FuzzReport> {
    let mut seeds = builtin_seeds(target);
    if let Some(dir) = corpus_dir {
        seeds.extend(load_corpus(&dir.join(target.name()))?);
    }
    let mut report = FuzzReport {
        target: target.name(),
        seed,
        cases: 0,
        accepted: 0,
        rejected: 0,
        failures: Vec::new(),
    };
    for case in 0..iters {
        // One fresh stream per case: a panic in case k never shifts the
        // inputs of cases k+1.. (failures stay independently addressable).
        let mut rng = Rng::new(seed ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(case + 1));
        let base = &seeds[(rng.next_u64() % seeds.len() as u64) as usize];
        let input = mutate(base, &mut rng);
        let text = String::from_utf8_lossy(&input).into_owned();
        report.cases += 1;
        match catch_unwind(AssertUnwindSafe(|| exercise(target, &text))) {
            Ok(true) => report.accepted += 1,
            Ok(false) => report.rejected += 1,
            Err(_) => report.failures.push(format!(
                "{}: panic on case {case} (seed {seed:#x}, {} bytes)",
                target.name(),
                input.len()
            )),
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_seeds_are_format_true() {
        // The un-mutated seeds must be *accepted* — otherwise every
        // mutation fuzzes the error path only.
        for target in FuzzTarget::ALL {
            for (i, s) in builtin_seeds(target).iter().enumerate() {
                let text = String::from_utf8(s.clone()).unwrap();
                assert!(exercise(target, &text), "{} seed {i} rejected", target.name());
            }
        }
    }

    #[test]
    fn campaigns_run_clean_and_deterministic() {
        for target in FuzzTarget::ALL {
            let a = run_target(target, None, 64, 0xF0CC).unwrap();
            let b = run_target(target, None, 64, 0xF0CC).unwrap();
            assert!(a.failures.is_empty(), "{}", a.render());
            assert_eq!(a.cases, 64);
            assert_eq!((a.accepted, a.rejected), (b.accepted, b.rejected));
            // Mutations must actually reach the reject path.
            assert!(a.rejected > 0, "{}", a.render());
        }
    }

    #[test]
    fn target_names_round_trip() {
        for target in FuzzTarget::ALL {
            assert_eq!(FuzzTarget::parse(target.name()), Some(target));
        }
        assert_eq!(FuzzTarget::parse("bogus"), None);
    }

    #[test]
    fn missing_corpus_directory_is_an_error() {
        let dir = std::env::temp_dir().join("zynq_fuzz_no_such_corpus");
        std::fs::remove_dir_all(&dir).ok();
        assert!(run_target(FuzzTarget::MemoJson, Some(&dir), 4, 1).is_err());
    }

    #[test]
    fn committed_corpus_loads_when_present() {
        // The checked-in corpus (repo root `rust/fuzz/corpus/`) is what CI
        // fuzzes; guard that its layout stays loadable. Skip silently when
        // the test runs from an unexpected cwd.
        let dir = Path::new("rust/fuzz/corpus");
        if !dir.exists() {
            return;
        }
        for target in FuzzTarget::ALL {
            let report = run_target(target, Some(dir), 32, 0xBEEF).unwrap();
            assert!(report.failures.is_empty(), "{}", report.render());
        }
    }
}
