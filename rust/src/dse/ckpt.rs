//! Sweep checkpoint/resume — the `.ckpt` sidecar of a recoverable sweep.
//!
//! A recoverable warm sweep (`prune::explore_pruned_warm_recoverable` via
//! [`RecoverySession`]) persists two sidecars next to the memo file: the
//! append-only `.wal` journal of evaluated points
//! ([`SweepJournal`](super::SweepJournal)) and this module's `.ckpt`
//! checkpoint, written atomically once per sweep — after candidate
//! ordering, before the first evaluation round. The checkpoint pins the
//! one piece of sweep state a resume cannot re-derive: the **candidate
//! processing order**. A resumed run serves the journal-restored points as
//! memo hits, so a freshly built order would exclude them and shift every
//! round boundary — and with it which candidates the frozen-frontier bound
//! cut skips, i.e. the returned ranking. Replaying the checkpointed order
//! (done candidates skip their slot without evaluating) keeps the resumed
//! run's final ranking and saved memo bit-identical to an uninterrupted
//! one.
//!
//! Each checkpointed job carries a [`space_fingerprint`] of everything the
//! order was derived from; a resume whose fingerprint differs (changed
//! space, objective, order mode, board, …) silently falls back to a fresh
//! order instead of replaying a stale one. Both sidecars are deleted by
//! the atomic [`EvalMemo::save`](super::EvalMemo::save) that makes their
//! contents durable in the memo proper.

use std::path::{Path, PathBuf};

use crate::util::fnv::Fnv;
use crate::util::json::{obj, Value};
use crate::util::persist;

use super::warm::{SweepJournal, WalRecovery};
use super::{DseSpace, Objective, OrderMode};

/// Schema version of the `.ckpt` sidecar.
pub const CKPT_SCHEMA_VERSION: i64 = 1;

/// Fingerprint of one sweep job's *shape*: everything that determines the
/// candidate list and its processing order — the memo context fingerprint
/// (program + board + part + cost-model constants,
/// [`context_fingerprint`](super::warm::context_fingerprint)), the DSE
/// space, the objective and the order mode. A resumed sweep only replays a
/// checkpointed order when this fingerprint matches, so a checkpoint left
/// by a different query can never silently reorder (or truncate) a sweep.
pub fn space_fingerprint(
    ctx_fp: u64,
    space: &DseSpace,
    objective: Objective,
    order: OrderMode,
) -> u64 {
    let mut h = Fnv::new();
    h.u64(CKPT_SCHEMA_VERSION as u64);
    h.u64(ctx_fp);
    h.bool(space.mixed);
    h.u64(space.kernels.len() as u64);
    for ks in &space.kernels {
        h.str(&ks.kernel);
        h.u64(ks.unrolls.len() as u64);
        for &u in &ks.unrolls {
            h.u64(u as u64);
        }
        h.u64(ks.max_instances as u64);
        h.bool(ks.try_smp);
    }
    h.u64(match objective {
        Objective::Time => 0,
        Objective::Energy => 1,
        Objective::Edp => 2,
    });
    h.u64(match order {
        OrderMode::Fifo => 0,
        OrderMode::BoundAsc => 1,
        OrderMode::Ranked => 2,
    });
    h.finish()
}

/// One job's checkpointed processing order.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CheckpointJob {
    /// [`space_fingerprint`] of the job at checkpoint time.
    pub space_fp: u64,
    /// Candidate indices in processing order (see
    /// [`OrderMode`](super::OrderMode)): exactly `JobState::order` of the
    /// interrupted run, including candidates that have since been
    /// journal-restored (they are skipped, not re-evaluated, on resume).
    pub order: Vec<usize>,
}

/// The parsed `.ckpt` document: per-job candidate orders of an in-flight
/// recoverable sweep, in sweep input order.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SweepCheckpoint {
    /// Checkpointed jobs, in sweep input order.
    pub jobs: Vec<CheckpointJob>,
}

impl SweepCheckpoint {
    /// Path of the checkpoint sidecar of a memo file.
    pub fn ckpt_path(memo_path: &Path) -> PathBuf {
        PathBuf::from(format!("{}.ckpt", memo_path.display()))
    }

    /// Serialize to the on-disk JSON document.
    pub fn to_json(&self) -> String {
        let jobs: Vec<Value> = self
            .jobs
            .iter()
            .map(|j| {
                obj(vec![
                    ("space_fp", format!("{:016x}", j.space_fp).into()),
                    (
                        "order",
                        Value::Arr(j.order.iter().map(|&i| Value::Int(i as i64)).collect()),
                    ),
                ])
            })
            .collect();
        obj(vec![
            ("version", CKPT_SCHEMA_VERSION.into()),
            ("estimator", env!("CARGO_PKG_VERSION").into()),
            ("jobs", Value::Arr(jobs)),
        ])
        .to_json()
    }

    /// Parse a checkpoint document; errors name the offending field.
    pub fn from_json(text: &str) -> anyhow::Result<Self> {
        let v = crate::util::json::parse(text)
            .map_err(|e| anyhow::anyhow!("checkpoint: {e}"))?;
        let ver = v.get("version").and_then(Value::as_i64).unwrap_or(-1);
        anyhow::ensure!(
            ver == CKPT_SCHEMA_VERSION,
            "checkpoint schema v{ver} != v{CKPT_SCHEMA_VERSION}"
        );
        let est = v.get("estimator").and_then(Value::as_str).unwrap_or("");
        anyhow::ensure!(
            est == env!("CARGO_PKG_VERSION"),
            "checkpoint written by estimator v{est}, this is v{}",
            env!("CARGO_PKG_VERSION")
        );
        let jobs_v = v
            .get("jobs")
            .and_then(Value::as_arr)
            .ok_or_else(|| anyhow::anyhow!("checkpoint misses 'jobs'"))?;
        let mut jobs = Vec::with_capacity(jobs_v.len());
        for (ji, j) in jobs_v.iter().enumerate() {
            let fp_s = j
                .get("space_fp")
                .and_then(Value::as_str)
                .ok_or_else(|| anyhow::anyhow!("checkpoint job {ji} misses 'space_fp'"))?;
            let space_fp = u64::from_str_radix(fp_s, 16)
                .map_err(|_| anyhow::anyhow!("checkpoint job {ji}: bad space_fp '{fp_s}'"))?;
            let order_v = j
                .get("order")
                .and_then(Value::as_arr)
                .ok_or_else(|| anyhow::anyhow!("checkpoint job {ji} misses 'order'"))?;
            let mut order = Vec::with_capacity(order_v.len());
            for o in order_v {
                let i = o
                    .as_u64()
                    .ok_or_else(|| anyhow::anyhow!("checkpoint job {ji}: bad order entry"))?;
                order.push(i as usize);
            }
            jobs.push(CheckpointJob { space_fp, order });
        }
        Ok(Self { jobs })
    }
}

/// The IO half of a recoverable sweep: the append-only
/// [`SweepJournal`](super::SweepJournal), what a previous journal replay
/// restored into the loaded memo, and — on resume — the interrupted run's
/// [`SweepCheckpoint`].
pub struct RecoverySession {
    journal: SweepJournal,
    ckpt_path: PathBuf,
    recovered: WalRecovery,
    checkpoint: Option<SweepCheckpoint>,
}

impl RecoverySession {
    /// Open a recovery session next to `memo_path`. `recovered` is what
    /// [`EvalMemo::load_with_recovery`](super::EvalMemo::load_with_recovery)
    /// replayed from the journal (if anything); with `resume` the `.ckpt`
    /// sidecar is additionally loaded so the sweep replays the interrupted
    /// run's candidate orders. A missing checkpoint is not an error (a
    /// crash may predate the first checkpoint write); a corrupt one is
    /// quarantined and ignored.
    pub fn open(
        memo_path: &Path,
        recovered: Option<WalRecovery>,
        resume: bool,
    ) -> anyhow::Result<Self> {
        let ckpt_path = SweepCheckpoint::ckpt_path(memo_path);
        let mut checkpoint = None;
        if resume {
            if let Ok(text) = std::fs::read_to_string(&ckpt_path) {
                match SweepCheckpoint::from_json(&text) {
                    Ok(c) => checkpoint = Some(c),
                    Err(e) => {
                        let note = match persist::quarantine(&ckpt_path) {
                            Ok(bak) => format!("quarantined to {}", bak.display()),
                            Err(qe) => format!("quarantine failed: {qe}"),
                        };
                        eprintln!(
                            "warning: corrupt sweep checkpoint {}: {e:#}; {note}; \
                             resuming without order replay",
                            ckpt_path.display()
                        );
                    }
                }
            }
        }
        Ok(Self {
            journal: SweepJournal::open(memo_path)?,
            ckpt_path,
            recovered: recovered.unwrap_or_default(),
            checkpoint,
        })
    }

    /// What the journal replay restored into the loaded memo.
    pub fn recovered(&self) -> &WalRecovery {
        &self.recovered
    }

    /// The journal to log context snapshots, points and round commits to.
    pub fn journal(&mut self) -> &mut SweepJournal {
        &mut self.journal
    }

    /// The checkpointed candidate order of job `ji` — only when a resume
    /// checkpoint is loaded *and* its job fingerprint matches (a changed
    /// space, objective or order mode falls back to a fresh order).
    pub fn checkpoint_order(&self, ji: usize, space_fp: u64) -> Option<&[usize]> {
        let job = self.checkpoint.as_ref()?.jobs.get(ji)?;
        (job.space_fp == space_fp).then_some(job.order.as_slice())
    }

    /// Atomically persist the per-job `(space fingerprint, order)` pairs as
    /// the sweep's checkpoint. Called once per sweep — after ordering,
    /// before the first round — so a crash at any later point can replay
    /// the exact round boundaries.
    pub fn save_orders(&mut self, orders: &[(u64, &[usize])]) -> anyhow::Result<()> {
        let ckpt = SweepCheckpoint {
            jobs: orders
                .iter()
                .map(|&(space_fp, order)| CheckpointJob {
                    space_fp,
                    order: order.to_vec(),
                })
                .collect(),
        };
        persist::write_atomic(&self.ckpt_path, ckpt.to_json().as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::KernelSpace;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("zynq_ckpt_{name}"));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn small_space() -> DseSpace {
        DseSpace {
            kernels: vec![KernelSpace {
                kernel: "mm".into(),
                unrolls: vec![8, 16],
                max_instances: 2,
                try_smp: true,
            }],
            mixed: false,
        }
    }

    #[test]
    fn checkpoint_roundtrips_through_json() {
        let ckpt = SweepCheckpoint {
            jobs: vec![
                CheckpointJob {
                    space_fp: 0xdead_beef_0123_4567,
                    order: vec![3, 0, 2, 1],
                },
                CheckpointJob {
                    space_fp: 7,
                    order: vec![],
                },
            ],
        };
        let back = SweepCheckpoint::from_json(&ckpt.to_json()).unwrap();
        assert_eq!(back, ckpt);
    }

    #[test]
    fn checkpoint_rejects_schema_and_field_corruption() {
        assert!(SweepCheckpoint::from_json("not json").is_err());
        assert!(SweepCheckpoint::from_json("{\"version\": 999}").is_err());
        let doc = format!(
            "{{\"version\": {CKPT_SCHEMA_VERSION}, \"estimator\": \"{}\", \
             \"jobs\": [{{\"space_fp\": \"xyz\", \"order\": []}}]}}",
            env!("CARGO_PKG_VERSION")
        );
        let err = SweepCheckpoint::from_json(&doc).unwrap_err().to_string();
        assert!(err.contains("space_fp"), "{err}");
    }

    #[test]
    fn space_fingerprint_separates_queries() {
        let space = small_space();
        let base = space_fingerprint(1, &space, Objective::Time, OrderMode::BoundAsc);
        assert_eq!(
            base,
            space_fingerprint(1, &space, Objective::Time, OrderMode::BoundAsc),
            "fingerprint must be stable"
        );
        assert_ne!(base, space_fingerprint(2, &space, Objective::Time, OrderMode::BoundAsc));
        assert_ne!(base, space_fingerprint(1, &space, Objective::Edp, OrderMode::BoundAsc));
        assert_ne!(base, space_fingerprint(1, &space, Objective::Time, OrderMode::Ranked));
        let mut wider = small_space();
        wider.kernels[0].unrolls.push(32);
        assert_ne!(base, space_fingerprint(1, &wider, Objective::Time, OrderMode::BoundAsc));
    }

    #[test]
    fn session_replays_orders_only_on_fingerprint_match() {
        let d = tmpdir("session");
        let memo_path = d.join("memo.json");
        let mut s = RecoverySession::open(&memo_path, None, false).unwrap();
        s.save_orders(&[(11, &[2usize, 0, 1][..]), (22, &[0usize][..])])
            .unwrap();
        drop(s);

        let resumed = RecoverySession::open(&memo_path, None, true).unwrap();
        assert_eq!(resumed.checkpoint_order(0, 11), Some(&[2usize, 0, 1][..]));
        assert_eq!(resumed.checkpoint_order(1, 22), Some(&[0usize][..]));
        assert_eq!(resumed.checkpoint_order(0, 99), None, "fingerprint mismatch");
        assert_eq!(resumed.checkpoint_order(2, 11), None, "no such job");
        drop(resumed);

        let fresh = RecoverySession::open(&memo_path, None, false).unwrap();
        assert_eq!(
            fresh.checkpoint_order(0, 11),
            None,
            "checkpoints replay only on explicit resume"
        );
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn session_quarantines_corrupt_checkpoint_on_resume() {
        let d = tmpdir("corrupt");
        let memo_path = d.join("memo.json");
        let ckpt_path = SweepCheckpoint::ckpt_path(&memo_path);
        std::fs::write(&ckpt_path, "torn{garbage").unwrap();
        let s = RecoverySession::open(&memo_path, None, true).unwrap();
        assert_eq!(s.checkpoint_order(0, 0), None);
        assert!(!ckpt_path.exists(), "corrupt checkpoint moved aside");
        let bak = PathBuf::from(format!("{}.bak.1", ckpt_path.display()));
        assert_eq!(std::fs::read(&bak).unwrap(), b"torn{garbage");
        std::fs::remove_dir_all(&d).ok();
    }
}
