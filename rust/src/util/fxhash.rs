//! FxHash — the rustc-internal multiply-xor hasher, reimplemented locally
//! (the `fxhash`/`rustc-hash` crates are not in the vendored set).
//!
//! Not DoS-resistant; used only for internal maps keyed by addresses and
//! dense ids where SipHash showed up at ~18% of the simulation profile
//! (EXPERIMENTS.md §Perf).

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// The FxHash state.
#[derive(Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, i: u64) {
        self.hash = (self.hash.rotate_left(5) ^ i).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for chunk in bytes.chunks(8) {
            let mut buf = [0u8; 8];
            buf[..chunk.len()].copy_from_slice(chunk);
            self.add_to_hash(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u8(&mut self, i: u8) {
        self.add_to_hash(i as u64);
    }
    #[inline]
    fn write_u16(&mut self, i: u16) {
        self.add_to_hash(i as u64);
    }
    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.add_to_hash(i as u64);
    }
    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.add_to_hash(i);
    }
    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn finish(&self) -> u64 {
        // Finalizer: fold the high bits down. Without this, page-aligned
        // keys (tile addresses are 0x1000 multiples) leave the low bits of
        // `hash * SEED` all zero, and hashbrown indexes buckets by the low
        // bits — instant pathological collisions (observed as a 3x
        // simulation slowdown before this line existed).
        self.hash ^ (self.hash >> 32)
    }
}

/// `BuildHasher` for [`FxHasher`].
pub type FxBuildHasher = BuildHasherDefault<FxHasher>;
/// `std::collections::HashMap` keyed with FxHash.
pub type FxHashMap<K, V> = HashMap<K, V, FxBuildHasher>;
/// `std::collections::HashSet` keyed with FxHash.
pub type FxHashSet<T> = HashSet<T, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distributes_sequential_keys() {
        // Sequential u64 keys must not collide in the low bits (the part
        // hash tables use).
        let mut buckets = [0u32; 64];
        for i in 0..64_000u64 {
            let mut h = FxHasher::default();
            h.write_u64(i);
            buckets[(h.finish() % 64) as usize] += 1;
        }
        let max = *buckets.iter().max().unwrap();
        let min = *buckets.iter().min().unwrap();
        assert!(max < 2 * min.max(1), "skewed: {min}..{max}");
    }

    #[test]
    fn distributes_page_aligned_keys() {
        // The regression case: 4 KiB-aligned addresses (task tile buffers).
        let mut buckets = [0u32; 64];
        for i in 0..64_000u64 {
            let mut h = FxHasher::default();
            h.write_u64(i * 0x1000);
            buckets[(h.finish() % 64) as usize] += 1;
        }
        let max = *buckets.iter().max().unwrap();
        let min = *buckets.iter().min().unwrap();
        assert!(max < 2 * min.max(1), "skewed: {min}..{max}");
    }

    #[test]
    fn map_and_set_work() {
        let mut m: FxHashMap<u64, u32> = FxHashMap::default();
        for i in 0..1000 {
            m.insert(i * 0x1000, i as u32);
        }
        assert_eq!(m.len(), 1000);
        assert_eq!(m[&0x5000], 5);
        let mut s: FxHashSet<u16> = FxHashSet::default();
        s.insert(7);
        assert!(s.contains(&7));
    }

    #[test]
    fn byte_writes_consistent() {
        let mut a = FxHasher::default();
        a.write(b"hello world!");
        let mut b = FxHasher::default();
        b.write(b"hello world!");
        assert_eq!(a.finish(), b.finish());
        let mut c = FxHasher::default();
        c.write(b"hello worl!d");
        assert_ne!(a.finish(), c.finish());
    }
}
