//! Sweep-engine guarantees: parallel `explore()` is bit-identical to the
//! serial path (any worker count, any objective), `SweepContext` cached
//! estimation equals a fresh `sim::estimate` for random co-designs
//! (seeded forall harness, same style as `proptests.rs`), and the
//! delta-evaluation fast path (`SweepWorker::evaluate_delta`) is bitwise
//! identical to the scratch oracle across random neighbor chains, all
//! three pruned order modes, and worker counts 1/2/4.

use zynq_estimator::apps::{cholesky::Cholesky, matmul::Matmul};
use zynq_estimator::config::{BoardConfig, CoDesign};
use zynq_estimator::coordinator::task::{
    Dep, Dir, KernelDecl, KernelProfile, TaskProgram, Targets,
};
use zynq_estimator::dse::{sweep, DsePoint, DseSpace, Objective, OrderMode, SweepContext};
use zynq_estimator::hls::FpgaPart;
use zynq_estimator::util::Rng;

fn forall(iters: u64, base_seed: u64, f: impl Fn(u64, &mut Rng)) {
    for i in 0..iters {
        let seed = base_seed.wrapping_add(i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(seed);
        f(seed, &mut rng);
    }
}

/// Random task program: 1-4 kernels (always SMP-capable, sometimes FPGA),
/// up to 60 tasks over a small shared address pool so dependences collide.
fn random_program(rng: &mut Rng) -> TaskProgram {
    let mut p = TaskProgram::new("prop");
    let n_kernels = rng.gen_range(1, 5);
    for k in 0..n_kernels {
        let fpga = rng.next_f64() < 0.7;
        p.add_kernel(KernelDecl {
            name: format!("k{k}"),
            targets: Targets { smp: true, fpga },
            profile: KernelProfile {
                flops: rng.gen_range(1_000, 1_000_000),
                inner_trip: rng.gen_range(1_000, 500_000),
                in_bytes: rng.gen_range(256, 65_536),
                out_bytes: rng.gen_range(256, 32_768),
                dtype_bytes: if rng.next_f64() < 0.5 { 4 } else { 8 },
                divsqrt: rng.next_f64() < 0.3,
            },
        });
    }
    let n_tasks = rng.gen_range(1, 61);
    let pool: Vec<u64> = (0..12).map(|i| 0x1000 + i * 0x1000).collect();
    for _ in 0..n_tasks {
        let kernel = rng.gen_range(0, n_kernels) as u16;
        let n_deps = rng.gen_range(1, 4);
        let mut deps = Vec::new();
        let mut used = std::collections::HashSet::new();
        for _ in 0..n_deps {
            let addr = pool[rng.gen_range(0, pool.len() as u64) as usize];
            if !used.insert(addr) {
                continue;
            }
            let dir = match rng.gen_range(0, 3) {
                0 => Dir::In,
                1 => Dir::Out,
                _ => Dir::InOut,
            };
            deps.push(Dep {
                addr,
                len: rng.gen_range(64, 16_384),
                dir,
            });
        }
        if deps.is_empty() {
            deps.push(Dep::inout(pool[0], 64));
        }
        p.add_task(kernel, rng.gen_range(1_000, 2_000_000), deps);
    }
    p
}

fn random_codesign(rng: &mut Rng, p: &TaskProgram) -> CoDesign {
    let mut cd = CoDesign::new("prop");
    for k in &p.kernels {
        if k.targets.fpga {
            let n_acc = rng.gen_range(0, 3);
            for _ in 0..n_acc {
                let unroll = 1 << rng.gen_range(1, 5); // 2..16
                cd = cd.with_accel(&k.name, unroll);
            }
            if n_acc > 0 && rng.next_f64() < 0.5 {
                cd = cd.with_smp(&k.name);
            }
        }
    }
    cd
}

fn assert_points_bit_identical(a: &[DsePoint], b: &[DsePoint], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: point count");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.codesign.name, y.codesign.name, "{what}: name at rank {i}");
        assert_eq!(
            x.codesign.accels, y.codesign.accels,
            "{what}: accels at rank {i}"
        );
        assert_eq!(
            x.est_ms.to_bits(),
            y.est_ms.to_bits(),
            "{what}: est_ms at rank {i}"
        );
        assert_eq!(
            x.energy_j.to_bits(),
            y.energy_j.to_bits(),
            "{what}: energy_j at rank {i}"
        );
        assert_eq!(x.edp.to_bits(), y.edp.to_bits(), "{what}: edp at rank {i}");
        assert_eq!(
            x.fabric_util.to_bits(),
            y.fabric_util.to_bits(),
            "{what}: fabric_util at rank {i}"
        );
    }
}

#[test]
fn parallel_explore_is_bit_identical_to_serial() {
    let board = BoardConfig::zynq706();
    let part = FpgaPart::xc7z045();
    for (name, program) in [
        ("matmul", Matmul::new(512, 64).build_program(&board)),
        ("cholesky", Cholesky::new(256, 64).build_program(&board)),
    ] {
        let space = DseSpace::from_program(&program);
        let ctx = SweepContext::for_space(&program, &board, &part, &space);
        for objective in [Objective::Time, Objective::Energy, Objective::Edp] {
            let serial = ctx.explore(&space, objective, 1);
            for workers in [2, 3, 4, 8] {
                let parallel = ctx.explore(&space, objective, workers);
                assert_points_bit_identical(
                    &serial,
                    &parallel,
                    &format!("{name}/{objective:?}/workers={workers}"),
                );
            }
        }
    }
}

#[test]
fn parallel_explore_matches_seed_rebuild_baseline() {
    let board = BoardConfig::zynq706();
    let part = FpgaPart::xc7z045();
    let program = Matmul::new(512, 64).build_program(&board);
    let space = DseSpace::from_program(&program);
    let baseline =
        sweep::explore_rebuild_baseline(&program, &board, &part, &space, Objective::Time)
            .unwrap();
    let ctx = SweepContext::for_space(&program, &board, &part, &space);
    let parallel = ctx.explore(&space, Objective::Time, 4);
    assert_points_bit_identical(&baseline, &parallel, "matmul vs seed baseline");
}

#[test]
fn free_explore_wrapper_still_ranks_like_the_seed() {
    // The public entry point (parallel by default) must keep the seed's
    // headline result: the 2x half-unroll matmul discovery.
    let board = BoardConfig::zynq706();
    let program = Matmul::new(512, 128).build_program(&board);
    let space = DseSpace::from_program(&program);
    let pts = zynq_estimator::dse::explore(
        &program,
        &board,
        &FpgaPart::xc7z045(),
        &space,
        Objective::Time,
    )
    .unwrap();
    assert!(!pts.is_empty());
    for w in pts.windows(2) {
        assert!(w[0].est_ms <= w[1].est_ms, "ranking must be sorted");
    }
}

#[test]
fn prop_cached_estimation_equals_fresh_estimate() {
    let board = BoardConfig::zynq706();
    forall(60, 0x5EEB, |seed, rng| {
        let p = random_program(rng);
        let ctx = SweepContext::new(&p, &board, FpgaPart::xc7z045());
        for _ in 0..4 {
            let cd = random_codesign(rng, &p);
            let fresh = zynq_estimator::sim::estimate(&p, &cd, &board);
            let cached = ctx.estimate(&cd);
            match (fresh, cached) {
                (Ok(f), Ok(c)) => {
                    assert_eq!(f.makespan, c.makespan, "seed {seed}");
                    assert_eq!(f.tasks_on_smp, c.tasks_on_smp, "seed {seed}");
                    assert_eq!(f.tasks_on_accel, c.tasks_on_accel, "seed {seed}");
                    assert_eq!(f.device_busy, c.device_busy, "seed {seed}");
                    assert_eq!(f.segments.len(), c.segments.len(), "seed {seed}");
                }
                (Err(_), Err(_)) => {} // both reject: fine
                (f, c) => panic!(
                    "seed {seed}: paths disagree on feasibility (fresh ok={}, cached ok={})",
                    f.is_ok(),
                    c.is_ok()
                ),
            }
        }
    });
}

#[test]
fn prop_concurrent_identical_dse_requests_coalesce_to_one_evaluation() {
    // Service-layer determinism: N clients firing the same `dse` request
    // at one daemon must cost exactly one evaluation pass in total, for
    // any worker count. Clients that arrive while the leader is in
    // flight park and receive a clone of its reply (bitwise identical);
    // a client that arrives after completion re-runs warm and evaluates
    // nothing — either way the memo sees one evaluation.
    use std::sync::{Arc, Barrier};
    use zynq_estimator::service::{ServeConfig, Service};
    forall(6, 0xC0A1E5CE, |seed, rng| {
        let workers = 1 + rng.gen_range(0, 4) as usize;
        let n_clients = 2 + rng.gen_range(0, 6) as usize;
        let n = if rng.next_f64() < 0.5 { 128 } else { 256 };
        let cfg = ServeConfig {
            workers,
            ..ServeConfig::default()
        };
        let svc = Arc::new(Service::new(BoardConfig::zynq706(), cfg).unwrap());
        let req = format!(r#"{{"req":"dse","app":"matmul","n":{n},"top":5}}"#);
        let barrier = Arc::new(Barrier::new(n_clients));
        let handles: Vec<_> = (0..n_clients)
            .map(|_| {
                let svc = Arc::clone(&svc);
                let req = req.clone();
                let barrier = Arc::clone(&barrier);
                std::thread::spawn(move || {
                    barrier.wait();
                    svc.handle_line(&req).0.expect("dse must answer")
                })
            })
            .collect();
        let responses: Vec<String> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let evaluated = |r: &str| {
            zynq_estimator::util::json::parse(r)
                .unwrap()
                .get("evaluated")
                .and_then(|v| v.as_u64())
                .unwrap()
        };
        let cold: Vec<&String> = responses.iter().filter(|r| evaluated(r) > 0).collect();
        assert!(!cold.is_empty(), "seed {seed}: someone must have evaluated");
        for r in &cold[1..] {
            assert_eq!(
                **r, *cold[0],
                "seed {seed} workers={workers}: coalesced responses diverged"
            );
        }
        assert_eq!(
            svc.evaluated(),
            evaluated(cold[0]),
            "seed {seed} workers={workers}: more than one evaluation pass for {n_clients} clients"
        );
        assert_eq!(svc.requests(), n_clients as u64, "seed {seed}");
        assert_eq!(svc.errors(), 0, "seed {seed}");
    });
}

#[test]
fn prop_worker_reuse_is_stateless_across_points() {
    // Evaluating A, then B, then A again through one reused worker must
    // reproduce A exactly — i.e. `Simulator::reset` leaks nothing.
    let board = BoardConfig::zynq706();
    let part = FpgaPart::xc7z045();
    forall(40, 0xA11C, |seed, rng| {
        let p = random_program(rng);
        let ctx = SweepContext::new(&p, &board, part.clone());
        let mut w = ctx.worker();
        let a = random_codesign(rng, &p);
        let b = random_codesign(rng, &p);
        let r1 = w.evaluate(&a);
        let _ = w.evaluate(&b);
        let r2 = w.evaluate(&a);
        match (r1, r2) {
            (Some(x), Some(y)) => {
                assert_eq!(x.est_ms.to_bits(), y.est_ms.to_bits(), "seed {seed}");
                assert_eq!(x.energy_j.to_bits(), y.energy_j.to_bits(), "seed {seed}");
            }
            (None, None) => {}
            _ => panic!("seed {seed}: reused worker changed feasibility"),
        }
    });
}

// ---------------------------------------------------------------------------
// Incremental re-simulation (delta-evaluation of neighboring sweep points).
// Contract under test: delta == scratch, bit for bit, for every point and
// every worker count; the reuse counters are a pure function of the
// candidate list (never of thread timing); an unsafe delta (changed kernel
// at the critical-path root) falls back to scratch.
// ---------------------------------------------------------------------------

/// Two-kernel pipeline with the changed kernel *off* the critical-path
/// root: every `tail` task reads a block a `head` task wrote, so the first
/// event whose timing depends on `tail` comes strictly after the head
/// work — a tail-only delta has a non-empty reusable prefix by
/// construction.
fn head_tail_program() -> TaskProgram {
    let mut p = TaskProgram::new("headtail");
    for name in ["head", "tail"] {
        p.add_kernel(KernelDecl {
            name: name.to_string(),
            targets: Targets {
                smp: true,
                fpga: true,
            },
            profile: KernelProfile {
                flops: 200_000,
                inner_trip: 100_000,
                in_bytes: 16_384,
                out_bytes: 8_192,
                dtype_bytes: 4,
                divsqrt: false,
            },
        });
    }
    for i in 0..4u64 {
        p.add_task(
            0,
            500_000,
            vec![Dep {
                addr: 0x1000 + i * 0x100,
                len: 4096,
                dir: Dir::Out,
            }],
        );
    }
    for i in 0..4u64 {
        p.add_task(
            1,
            500_000,
            vec![Dep {
                addr: 0x1000 + i * 0x100,
                len: 4096,
                dir: Dir::In,
            }],
        );
    }
    p
}

/// A neighbor chain over [`head_tail_program`]: consecutive candidates
/// differ only in `tail`'s unroll, so `delta_chains` keeps them in one
/// chain. `prefix` keeps candidate names unique per test — the tagged
/// `delta.plan` faultpoint test must never match another test's points.
fn tail_chain(prefix: &str, n: usize) -> Vec<CoDesign> {
    (0..n)
        .map(|i| {
            let unroll = 1u32 << (i + 1);
            CoDesign::new(format!("{prefix}-u{unroll}"))
                .with_accel("head", 4)
                .with_accel("tail", unroll)
        })
        .collect()
}

#[test]
fn neighbor_chain_reuses_prefix_and_matches_scratch() {
    let board = BoardConfig::zynq706();
    let p = head_tail_program();
    let ctx = SweepContext::new(&p, &board, FpgaPart::xc7z045());
    let cands = tail_chain("tail", 5);
    let mut w = ctx.worker();
    let oracle: Vec<DsePoint> = cands.iter().filter_map(|cd| w.evaluate(cd)).collect();
    assert_eq!(oracle.len(), cands.len(), "all chain points must be runnable");
    let mut per_workers = Vec::new();
    for workers in [1, 2, 4] {
        let (points, stats) = ctx.evaluate_all_with_stats(&cands, workers);
        assert_points_bit_identical(&oracle, &points, &format!("headtail workers={workers}"));
        assert!(
            stats.hits > 0,
            "workers={workers}: no delta hit on a chain built for one: {stats:?}"
        );
        assert!(
            stats.suffix_events < stats.total_events,
            "workers={workers}: reused prefix must shrink the replayed suffix: {stats:?}"
        );
        per_workers.push(stats);
    }
    assert!(
        per_workers.windows(2).all(|s| s[0] == s[1]),
        "delta counters depend on worker count: {per_workers:?}"
    );
}

#[test]
fn root_kernel_chain_falls_back_to_scratch() {
    // Matmul has exactly one kernel, so the changed kernel sits at the
    // critical-path root: the first simulated event already depends on it,
    // no checkpoint can be captured, and every non-head chain position
    // must take the scratch fallback — with unchanged results.
    let board = BoardConfig::zynq706();
    let part = FpgaPart::xc7z045();
    let program = Matmul::new(256, 64).build_program(&board);
    let space = DseSpace::from_program(&program);
    let ctx = SweepContext::for_space(&program, &board, &part, &space);
    let cands = ctx.enumerate(&space);
    assert!(cands.len() > 1, "need a chain to exercise the delta path");
    let mut w = ctx.worker();
    let oracle: Vec<DsePoint> = cands.iter().filter_map(|cd| w.evaluate(cd)).collect();
    let (points, stats) = ctx.evaluate_all_with_stats(&cands, 2);
    assert_points_bit_identical(&oracle, &points, "matmul root fallback");
    assert_eq!(
        stats.hits, 0,
        "a root-kernel delta must never be applied: {stats:?}"
    );
    assert!(
        stats.fallbacks > 0,
        "the chain's non-head positions must fall back to scratch: {stats:?}"
    );
}

#[test]
fn prop_delta_evaluation_is_bit_identical_to_scratch() {
    // Random programs, random neighbor chains (consecutive candidates
    // differ in at most one kernel's variants — the odometer property
    // `delta_chains` exploits): the chained evaluation equals the
    // per-point scratch oracle bit for bit, and the reuse counters are
    // identical for workers 1, 2 and 4.
    let board = BoardConfig::zynq706();
    let part = FpgaPart::xc7z045();
    forall(40, 0xDE17A, |seed, rng| {
        let p = random_program(rng);
        let fpga: Vec<String> = p
            .kernels
            .iter()
            .filter(|k| k.targets.fpga)
            .map(|k| k.name.clone())
            .collect();
        if fpga.is_empty() {
            return;
        }
        let varied = fpga[rng.gen_range(0, fpga.len() as u64) as usize].clone();
        // Fixed base option per non-varied kernel; the varied kernel gets
        // a fresh random option at every chain position.
        let mut base: Vec<(String, u64, u32, bool)> = Vec::new();
        for name in &fpga {
            if *name == varied {
                continue;
            }
            let n_acc = rng.gen_range(0, 3);
            let unroll = 1u32 << rng.gen_range(1, 5);
            let smp = n_acc > 0 && rng.next_f64() < 0.5;
            base.push((name.clone(), n_acc, unroll, smp));
        }
        let len = rng.gen_range(2, 7);
        let mut chain = Vec::new();
        for i in 0..len {
            let mut cd = CoDesign::new(format!("chain-{i}"));
            for (name, n_acc, unroll, smp) in &base {
                for _ in 0..*n_acc {
                    cd = cd.with_accel(name, *unroll);
                }
                if *smp {
                    cd = cd.with_smp(name);
                }
            }
            let n_acc = rng.gen_range(1, 4);
            let unroll = 1u32 << rng.gen_range(1, 5);
            for _ in 0..n_acc {
                cd = cd.with_accel(&varied, unroll);
            }
            if rng.next_f64() < 0.5 {
                cd = cd.with_smp(&varied);
            }
            chain.push(cd);
        }
        let ctx = SweepContext::new(&p, &board, part.clone());
        let mut w = ctx.worker();
        let oracle: Vec<DsePoint> = chain.iter().filter_map(|cd| w.evaluate(cd)).collect();
        let mut per_workers = Vec::new();
        for workers in [1, 2, 4] {
            let (points, stats) = ctx.evaluate_all_with_stats(&chain, workers);
            assert_points_bit_identical(
                &oracle,
                &points,
                &format!("seed {seed} workers={workers}"),
            );
            per_workers.push(stats);
        }
        assert!(
            per_workers.windows(2).all(|s| s[0] == s[1]),
            "seed {seed}: delta counters depend on worker count: {per_workers:?}"
        );
    });
}

#[test]
fn pruned_explore_delta_matches_scratch_across_order_modes() {
    // All three candidate orders of the bound-guided sweep run on top of
    // the same chain executor: rankings and delta counters must be
    // bit-identical for workers 1/2/4, and every evaluated point must
    // equal a scratch re-evaluation of its co-design.
    let board = BoardConfig::zynq706();
    let part = FpgaPart::xc7z045();
    let program = Cholesky::new(256, 64).build_program(&board);
    let space = DseSpace::from_program(&program);
    let ctx = SweepContext::for_space(&program, &board, &part, &space);
    let mut oracle = ctx.worker();
    for order in [OrderMode::Fifo, OrderMode::BoundAsc, OrderMode::Ranked] {
        let (serial, serial_stats) = ctx.explore_pruned_with(&space, Objective::Time, 1, order);
        assert!(!serial.is_empty(), "{order:?}: empty pruned ranking");
        for p in &serial {
            let fresh = oracle
                .evaluate(&p.codesign)
                .expect("an evaluated point is runnable");
            assert_eq!(
                p.est_ms.to_bits(),
                fresh.est_ms.to_bits(),
                "{order:?}: delta diverged from scratch for {}",
                p.codesign.name
            );
            assert_eq!(
                p.energy_j.to_bits(),
                fresh.energy_j.to_bits(),
                "{order:?}: energy diverged from scratch for {}",
                p.codesign.name
            );
        }
        for workers in [2, 4] {
            let (points, stats) = ctx.explore_pruned_with(&space, Objective::Time, workers, order);
            assert_points_bit_identical(
                &serial,
                &points,
                &format!("{order:?} workers={workers}"),
            );
            assert_eq!(
                serial_stats, stats,
                "{order:?} workers={workers}: prune/delta counters diverged"
            );
        }
    }
}

#[test]
fn forced_delta_plan_fault_falls_back_without_changing_results() {
    // `delta.plan` is a *soft* faultpoint: an armed spec forces the
    // scratch fallback (it never errors or panics), so results must be
    // byte-identical with and without it. Tag the specs to this test's
    // candidate names so concurrent tests in this binary never match.
    use zynq_estimator::util::faultpoint;
    let board = BoardConfig::zynq706();
    let p = head_tail_program();
    let ctx = SweepContext::new(&p, &board, FpgaPart::xc7z045());
    let cands = tail_chain("forced", 4);
    let (clean, clean_stats) = ctx.evaluate_all_with_stats(&cands, 2);
    assert!(
        clean_stats.hits > 0,
        "precondition: the chain must hit the delta path: {clean_stats:?}"
    );
    let spec = cands
        .iter()
        .skip(1)
        .map(|c| format!("delta.plan#{:x}", faultpoint::str_tag(&c.name)))
        .collect::<Vec<_>>()
        .join(",");
    let guard = faultpoint::arm(&spec).unwrap();
    let (forced, forced_stats) = ctx.evaluate_all_with_stats(&cands, 2);
    drop(guard);
    assert_points_bit_identical(&clean, &forced, "forced delta.plan fallback");
    assert_eq!(
        forced_stats.hits, 0,
        "every non-head position must be forced to scratch: {forced_stats:?}"
    );
    assert_eq!(
        forced_stats.fallbacks,
        clean_stats.hits + clean_stats.fallbacks,
        "forced fallbacks must cover every non-head position"
    );
}
