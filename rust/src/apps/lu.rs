//! Tiled LU decomposition (no pivoting) — a fourth application, exercising
//! yet another dependence shape: the right-looking LU panel graph has both
//! cholesky-style panel chains *and* matmul-style trailing updates, with a
//! row/column asymmetry cholesky lacks.
//!
//! Kernel family (standard tiled LU):
//! ```c
//! #pragma omp task inout([BS*BS]A)                       // SMP only
//! void ludiag(double *A, int BS);                        // A = L*U in place
//! #pragma omp target device(fpga,smp)
//! #pragma omp task in([BS*BS]D) inout([BS*BS]A)
//! void trsm_row(double *D, double *A, int BS);           // A = L^-1 A
//! #pragma omp target device(fpga,smp)
//! #pragma omp task in([BS*BS]D) inout([BS*BS]A)
//! void trsm_col(double *D, double *A, int BS);           // A = A U^-1
//! #pragma omp target device(fpga,smp)
//! #pragma omp task in([BS*BS]L,[BS*BS]U) inout([BS*BS]A)
//! void lugemm(double *L, double *U, double *A, int BS);  // A -= L*U
//! ```
//!
//! Like the paper's cholesky, the diagonal factorization stays on the SMP
//! (divisions + no parallelism) and the three bulk kernels are FPGA
//! candidates.

use crate::config::{BoardConfig, CoDesign};
use crate::coordinator::task::{Dep, KernelDecl, KernelProfile, TaskProgram, Targets};

use super::smp_cycles_model;

const A_BASE: u64 = 0x9000_0000;

/// Full-resource and pair unrolls mirror the cholesky study.
pub const UNROLL_FR: u32 = 44;
/// Pair unroll: two accelerators of this size fit together.
pub const UNROLL_PAIR: u32 = 16;

#[derive(Clone, Copy, Debug)]
/// Tiled LU decomposition without pivoting (extension app).
pub struct Lu {
    /// Matrix dimension (elements).
    pub n: u64,
    /// Block (tile) dimension.
    pub bs: u64,
}

impl Lu {
    /// An `n`×`n` problem with `bs`×`bs` tiles (`n` divisible by `bs`).
    pub fn new(n: u64, bs: u64) -> Self {
        assert!(n % bs == 0);
        Self { n, bs }
    }

    /// Number of tile blocks per side.
    pub fn nb(&self) -> u64 {
        self.n / self.bs
    }

    fn tile_bytes(&self) -> u64 {
        self.bs * self.bs * 8
    }

    fn addr(&self, row: u64, col: u64) -> u64 {
        A_BASE + (row * self.nb() + col) * self.tile_bytes()
    }

    /// Kernel profiles (lugemm, trsm_row, trsm_col, ludiag).
    pub fn profiles(&self) -> [(&'static str, Targets, KernelProfile); 4] {
        let bs = self.bs;
        let tile = self.tile_bytes();
        [
            (
                "lugemm",
                Targets::BOTH,
                KernelProfile {
                    flops: 2 * bs * bs * bs,
                    inner_trip: bs * bs * bs,
                    in_bytes: 3 * tile,
                    out_bytes: tile,
                    dtype_bytes: 8,
                    divsqrt: false,
                },
            ),
            (
                "trsm_row",
                Targets::BOTH,
                KernelProfile {
                    flops: bs * bs * bs,
                    inner_trip: bs * bs * bs / 2,
                    in_bytes: 2 * tile,
                    out_bytes: tile,
                    dtype_bytes: 8,
                    divsqrt: false, // unit-lower solve: no division
                },
            ),
            (
                "trsm_col",
                Targets::BOTH,
                KernelProfile {
                    flops: bs * bs * bs,
                    inner_trip: bs * bs * bs / 2,
                    in_bytes: 2 * tile,
                    out_bytes: tile,
                    dtype_bytes: 8,
                    divsqrt: true, // divides by U's diagonal
                },
            ),
            (
                "ludiag",
                Targets::SMP,
                KernelProfile {
                    flops: 2 * bs * bs * bs / 3,
                    inner_trip: bs * bs * bs / 3,
                    in_bytes: tile,
                    out_bytes: tile,
                    dtype_bytes: 8,
                    divsqrt: true,
                },
            ),
        ]
    }

    /// Build the task program (right-looking tiled LU trace).
    pub fn build_program(&self, board: &BoardConfig) -> TaskProgram {
        let mut p = TaskProgram::new(&format!("lu{}-bs{}", self.n, self.bs));
        let mut ids = [0u16; 4];
        let mut cycles = [0u64; 4];
        for (i, (name, targets, profile)) in self.profiles().into_iter().enumerate() {
            cycles[i] = smp_cycles_model(&profile, board);
            ids[i] = p.add_kernel(KernelDecl {
                name: name.to_string(),
                targets,
                profile,
            });
        }
        let [gemm, trow, tcol, diag] = ids;
        let [c_gemm, c_trow, c_tcol, c_diag] = cycles;
        let nb = self.nb();
        let tb = self.tile_bytes();
        for k in 0..nb {
            p.add_task(diag, c_diag, vec![Dep::inout(self.addr(k, k), tb)]);
            for j in (k + 1)..nb {
                // row panel: A[k][j] = L(k,k)^-1 A[k][j]
                p.add_task(
                    trow,
                    c_trow,
                    vec![
                        Dep::input(self.addr(k, k), tb),
                        Dep::inout(self.addr(k, j), tb),
                    ],
                );
            }
            for i in (k + 1)..nb {
                // column panel: A[i][k] = A[i][k] U(k,k)^-1
                p.add_task(
                    tcol,
                    c_tcol,
                    vec![
                        Dep::input(self.addr(k, k), tb),
                        Dep::inout(self.addr(i, k), tb),
                    ],
                );
            }
            for i in (k + 1)..nb {
                for j in (k + 1)..nb {
                    // trailing update: A[i][j] -= A[i][k] * A[k][j]
                    p.add_task(
                        gemm,
                        c_gemm,
                        vec![
                            Dep::input(self.addr(i, k), tb),
                            Dep::input(self.addr(k, j), tb),
                            Dep::inout(self.addr(i, j), tb),
                        ],
                    );
                }
            }
        }
        p
    }
}

/// Co-design set analogous to Fig. 9 for the LU kernel family.
pub fn study_codesigns() -> Vec<CoDesign> {
    vec![
        CoDesign::new("FR-lugemm").with_accel("lugemm", UNROLL_FR),
        CoDesign::new("FR-trsm_row").with_accel("trsm_row", UNROLL_FR),
        CoDesign::new("FR-trsm_col").with_accel("trsm_col", UNROLL_FR),
        CoDesign::new("lugemm+trsm_row")
            .with_accel("lugemm", UNROLL_PAIR)
            .with_accel("trsm_row", UNROLL_PAIR),
        CoDesign::new("lugemm+trsm_col")
            .with_accel("lugemm", UNROLL_PAIR)
            .with_accel("trsm_col", UNROLL_PAIR),
        CoDesign::new("lugemm+lugemm")
            .with_accel("lugemm", UNROLL_PAIR)
            .with_accel("lugemm", UNROLL_PAIR),
    ]
}

/// Closed-form instance counts for NB blocks:
/// (lugemm, trsm_row, trsm_col, ludiag).
pub fn expected_counts(nb: u64) -> (u64, u64, u64, u64) {
    let diag = nb;
    let trow: u64 = (0..nb).map(|k| nb - k - 1).sum();
    let tcol = trow;
    let gemm: u64 = (0..nb).map(|k| (nb - k - 1) * (nb - k - 1)).sum();
    (gemm, trow, tcol, diag)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::deps::DepGraph;
    use crate::sim::{emulate, estimate};

    #[test]
    fn counts_match_closed_form() {
        let b = BoardConfig::zynq706();
        let app = Lu::new(512, 64); // NB = 8
        let p = app.build_program(&b);
        let h = p.instance_histogram();
        let (g, tr, tc, d) = expected_counts(8);
        assert_eq!(h["lugemm"] as u64, g);
        assert_eq!(h["trsm_row"] as u64, tr);
        assert_eq!(h["trsm_col"] as u64, tc);
        assert_eq!(h["ludiag"] as u64, d);
        assert_eq!(g, 140); // sum of squares 49+36+25+16+9+4+1
        assert!(p.validate().is_empty());
    }

    #[test]
    fn graph_structure() {
        let b = BoardConfig::zynq706();
        let p = Lu::new(256, 64).build_program(&b); // NB = 4
        let g = DepGraph::build(&p);
        assert!(g.respects_program_order());
        // Panel chain: diag -> trsm -> gemm per k, serialized across k on
        // the trailing submatrix: depth >= 3 * NB - 2.
        assert!(g.depth() >= 10, "depth {}", g.depth());
        // First diag is the only root (everything depends on panel 0
        // through the trailing update chain... row/col panels of k=0 do).
        assert!(g.roots().contains(&0));
    }

    #[test]
    fn study_runs_and_gemm_pairs_win() {
        let b = BoardConfig::zynq706();
        let app = Lu::new(512, 64);
        let p = app.build_program(&b);
        let mut results = Vec::new();
        for cd in study_codesigns() {
            let est = estimate(&p, &cd, &b).unwrap();
            assert!(est.validate().is_empty());
            results.push((cd.name.clone(), est.makespan_ms()));
        }
        // lugemm dominates the FLOPs: every pair containing it must beat
        // the FR variants of the small kernels.
        let ms = |name: &str| results.iter().find(|(n, _)| n == name).unwrap().1;
        assert!(ms("FR-lugemm") < ms("FR-trsm_row"));
        assert!(ms("FR-lugemm") < ms("FR-trsm_col"));
        let best = results
            .iter()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        assert!(best.0.contains("lugemm"), "winner: {}", best.0);
    }

    #[test]
    fn estimator_and_board_agree_on_trends() {
        let b = BoardConfig::zynq706();
        let app = Lu::new(512, 64);
        let p = app.build_program(&b);
        let mut est_v = Vec::new();
        let mut brd_v = Vec::new();
        for cd in study_codesigns() {
            est_v.push(estimate(&p, &cd, &b).unwrap().makespan_ms());
            brd_v.push(emulate(&p, &cd, &b).unwrap().makespan_ms());
        }
        let tau = crate::util::kendall_tau(&est_v, &brd_v);
        assert!(tau >= 0.7, "tau {tau}");
    }
}
