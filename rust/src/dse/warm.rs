//! Warm-start layer for the DSE engine — a persistent evaluation memo.
//!
//! The paper's promise is turning the co-design decision "from hours to
//! minutes"; after the sweep/prune/cross layers, the remaining redundancy
//! is *between* sweeps: a robustness study re-sweeps near-identical
//! spaces, a cross-board study sweeps sibling platforms, and an analyst
//! iterating on a space re-simulates points an earlier run already
//! evaluated. CEDR (Mack et al., 2022) and the hardware-HEFT work both
//! reuse prior schedule state across runs; the [`EvalMemo`] is that idea
//! applied to the estimator:
//!
//! * every evaluated point is recorded under a key that fingerprints
//!   **everything the evaluation depends on** — the task program (kernel
//!   declarations, profiles, every task's cycles and dependences), the
//!   board description, the FPGA part, and the estimator version — plus a
//!   canonical form of the co-design. A memo hit is therefore
//!   *bit-identical* to re-simulating by construction: two sweeps that
//!   share a key evaluated the exact same deterministic function. Any
//!   change to the program, board, part or estimator changes the
//!   fingerprint and misses cleanly (asserted by the warm-start property
//!   tests, which perturb each ingredient and check the memo refuses the
//!   hit);
//! * a warm sweep ([`SweepContext::explore_warm`]) returns hits without
//!   re-simulation and seeds its bound frontier with them, so bound-guided
//!   pruning starts from a warm incumbent. Seeded points are always
//!   members of the current sweep's own candidate set, which is what keeps
//!   the cut lossless — a frontier point that cuts a candidate is itself
//!   part of the returned ranking;
//! * the memo serializes through the repository's own JSON substrate
//!   ([`crate::util::json`]), with `f64` values stored as exact bit
//!   patterns so a save/load round-trip cannot perturb a single ULP. Each
//!   context also carries its time-energy **frontier** (the Pareto set of
//!   its recorded points) as a compact, report-friendly summary.
//!   Board-axis warm starts read the recorded *points* of sibling
//!   contexts ([`EvalMemo::sibling_points_ms`]) and scale them by the
//!   fabric-clock ratio as ordering priors.
//!
//! Lifecycle: `load_or_new` → any number of warm sweeps (each records its
//! new evaluations) → `save`. Memo files are versioned; a file written by
//! a different estimator version or schema is rejected on load instead of
//! silently serving stale numbers.

use std::collections::BTreeMap;
use std::path::Path;

use crate::config::CoDesign;
use crate::util::json::{arr, obj, parse, Value};

use super::sweep::SweepContext;
use super::DsePoint;

/// Memo file schema version (bumped on layout changes; also folded into
/// the context fingerprint so schema bumps invalidate old entries).
pub const MEMO_SCHEMA_VERSION: i64 = 1;

/// FNV-1a, used for the stable context fingerprint (the repository's
/// `FxHasher` is for hash *tables*; the memo needs a hash whose value is
/// part of a serialized file format, so it is pinned here explicitly).
struct Fnv(u64);

impl Fnv {
    fn new() -> Self {
        Fnv(0xcbf2_9ce4_8422_2325)
    }
    fn bytes(&mut self, b: &[u8]) {
        for &x in b {
            self.0 ^= x as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    fn u64(&mut self, v: u64) {
        self.bytes(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.bytes(s.as_bytes());
    }
    fn bool(&mut self, b: bool) {
        self.bytes(&[b as u8]);
    }
}

/// Fingerprint of everything a point evaluation depends on: the estimator
/// version, the task program (kernels, profiles, tasks, dependences), the
/// board description and the FPGA part. The swept [`DseSpace`] is
/// deliberately **not** part of the key — the memo exists to be shared
/// across spaces over the same (program, board, part) triple. The
/// board-emulator-only `emu` block is excluded too: estimator results do
/// not depend on it.
///
/// [`DseSpace`]: super::DseSpace
pub fn context_fingerprint(ctx: &SweepContext<'_>) -> u64 {
    let mut h = Fnv::new();
    h.str(env!("CARGO_PKG_VERSION"));
    h.u64(MEMO_SCHEMA_VERSION as u64);
    let p = ctx.program;
    h.str(&p.app_name);
    h.u64(p.kernels.len() as u64);
    for k in &p.kernels {
        h.str(&k.name);
        h.bool(k.targets.smp);
        h.bool(k.targets.fpga);
        h.u64(k.profile.flops);
        h.u64(k.profile.inner_trip);
        h.u64(k.profile.in_bytes);
        h.u64(k.profile.out_bytes);
        h.u64(k.profile.dtype_bytes as u64);
        h.bool(k.profile.divsqrt);
    }
    h.u64(p.tasks.len() as u64);
    for t in &p.tasks {
        h.u64(t.kernel as u64);
        h.u64(t.smp_cycles);
        h.u64(t.creation_ns);
        h.u64(t.deps.len() as u64);
        for d in &t.deps {
            h.u64(d.addr);
            h.u64(d.len);
            h.str(d.dir.as_str());
        }
    }
    let b = ctx.board;
    h.str(&b.name);
    h.u64(b.smp_cores as u64);
    h.f64(b.smp_freq_mhz);
    h.f64(b.fabric_freq_mhz);
    h.bool(b.dma_in_scales);
    h.bool(b.dma_out_scales);
    h.f64(b.dma_bw_mbps);
    h.f64(b.dma_submit_us);
    h.f64(b.task_creation_us);
    h.f64(b.smp_flops_per_cycle);
    h.f64(b.smp_divsqrt_penalty);
    h.f64(b.smp_dp_penalty);
    h.f64(b.smp_l1_kb);
    h.f64(b.smp_cache_alpha);
    let part = &ctx.part;
    h.str(&part.name);
    h.u64(part.budget.luts);
    h.u64(part.budget.ffs);
    h.u64(part.budget.dsps);
    h.u64(part.budget.bram18);
    h.f64(part.routable_fraction);
    // Model constants that are code rather than config: the power model's
    // watts feed every energy/EDP figure, so a same-version tweak to
    // `PowerModel::default()` must miss instead of serving stale numbers.
    // (Structural changes to the cost model or scheduler still require a
    // MEMO_SCHEMA_VERSION bump — that is what the constant is for.)
    let pm = ctx.power_model();
    h.f64(pm.ps_static_w);
    h.f64(pm.smp_dynamic_w);
    h.f64(pm.pl_static_w);
    h.f64(pm.pl_static_per_util_w);
    h.f64(pm.w_per_dsp_100mhz);
    h.f64(pm.w_per_bram_100mhz);
    h.f64(pm.w_per_10kluts_100mhz);
    h.f64(pm.dma_dynamic_w);
    h.0
}

/// Canonical memo key of a co-design: sorted accelerator specs plus the
/// sorted, deduplicated "+ smp" kernel list. Two co-designs that simulate
/// identically (instance order is irrelevant to the engine) share one key.
pub fn codesign_key(cd: &CoDesign) -> String {
    let mut accels: Vec<String> = cd
        .accels
        .iter()
        .map(|a| format!("{}:U{}", a.kernel, a.unroll))
        .collect();
    accels.sort();
    let mut smp: Vec<&str> = cd.smp_kernels.iter().map(String::as_str).collect();
    smp.sort_unstable();
    smp.dedup();
    format!("{}|smp:{}", accels.join("+"), smp.join(","))
}

/// Stored evaluation result — `f64`s as exact bit patterns so JSON
/// round-trips are lossless.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct MemoPoint {
    est_ms: u64,
    energy_j: u64,
    edp: u64,
    fabric_util: u64,
}

/// A memo hit, decoded back to the evaluation's exact numbers.
#[derive(Clone, Copy, Debug)]
pub struct MemoValues {
    /// Estimated makespan (ms) — bit-identical to the recorded evaluation.
    pub est_ms: f64,
    /// Total platform energy (J).
    pub energy_j: f64,
    /// Energy-delay product (J·s).
    pub edp: f64,
    /// Fabric utilization in [0, 1].
    pub fabric_util: f64,
}

/// One (program, board, part) context of the memo: its recorded points
/// plus human-readable metadata for reports.
#[derive(Clone, Debug, Default)]
struct MemoContext {
    app: String,
    board: String,
    part: String,
    fabric_mhz: f64,
    points: BTreeMap<String, MemoPoint>,
}

impl MemoContext {
    /// Time-energy Pareto frontier of the recorded points (exact bits),
    /// sorted — the compact summary serialized next to the points.
    fn frontier(&self) -> Vec<(u64, u64)> {
        let pts: Vec<(f64, f64)> = self
            .points
            .values()
            .map(|p| (f64::from_bits(p.est_ms), f64::from_bits(p.energy_j)))
            .collect();
        let mut front: Vec<(u64, u64)> = super::front_indices(&pts)
            .into_iter()
            .map(|i| (pts[i].0.to_bits(), pts[i].1.to_bits()))
            .collect();
        front.sort_unstable();
        front.dedup();
        front
    }
}

/// Persistent `(context fingerprint, co-design) → evaluation` memo — see
/// the module docs for the exactness contract and lifecycle.
#[derive(Clone, Debug, Default)]
pub struct EvalMemo {
    contexts: BTreeMap<u64, MemoContext>,
}

impl EvalMemo {
    /// An empty memo.
    pub fn new() -> Self {
        Self::default()
    }

    /// Load a memo file, or start empty when the file does not exist yet.
    /// A malformed file, or one written by a different estimator version /
    /// schema, is an error (never silently served).
    pub fn load_or_new(path: &Path) -> anyhow::Result<Self> {
        if !path.exists() {
            return Ok(Self::new());
        }
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        Self::from_json(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
    }

    /// Save the memo (atomically enough for a CLI tool: write then rename
    /// is overkill here; the file is small and regenerable).
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json())
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
    }

    /// Number of contexts recorded.
    pub fn n_contexts(&self) -> usize {
        self.contexts.len()
    }

    /// Total recorded points across every context.
    pub fn n_points(&self) -> usize {
        self.contexts.values().map(|c| c.points.len()).sum()
    }

    /// Exact-hit lookup.
    pub fn lookup(&self, fingerprint: u64, key: &str) -> Option<MemoValues> {
        let p = self.contexts.get(&fingerprint)?.points.get(key)?;
        Some(MemoValues {
            est_ms: f64::from_bits(p.est_ms),
            energy_j: f64::from_bits(p.energy_j),
            edp: f64::from_bits(p.edp),
            fabric_util: f64::from_bits(p.fabric_util),
        })
    }

    /// Record one evaluated point under its context. Idempotent: a key can
    /// only ever map to one value (the evaluation is deterministic), so
    /// re-recording overwrites with identical bits.
    pub fn record(&mut self, ctx: &SweepContext<'_>, fingerprint: u64, key: &str, p: &DsePoint) {
        let entry = self.contexts.entry(fingerprint).or_insert_with(|| MemoContext {
            app: ctx.program.app_name.clone(),
            board: ctx.board.name.clone(),
            part: ctx.part.name.clone(),
            fabric_mhz: ctx.board.fabric_freq_mhz,
            points: BTreeMap::new(),
        });
        debug_assert_eq!(entry.fabric_mhz.to_bits(), ctx.board.fabric_freq_mhz.to_bits());
        entry.points.insert(
            key.to_string(),
            MemoPoint {
                est_ms: p.est_ms.to_bits(),
                energy_j: p.energy_j.to_bits(),
                edp: p.edp.to_bits(),
                fabric_util: p.fabric_util.to_bits(),
            },
        );
    }

    /// The `(est_ms, energy_j)` frontier of one context (exact values),
    /// sorted by ascending time — empty when the context is unknown.
    pub fn frontier(&self, fingerprint: u64) -> Vec<(f64, f64)> {
        self.contexts
            .get(&fingerprint)
            .map(|c| {
                c.frontier()
                    .into_iter()
                    .map(|(m, e)| (f64::from_bits(m), f64::from_bits(e)))
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Per-context `(key → est_ms)` map (diagnostics / tests). Empty when
    /// the context is unknown.
    pub fn points_ms(&self, fingerprint: u64) -> Vec<(String, f64)> {
        self.contexts
            .get(&fingerprint)
            .map(|c| {
                c.points
                    .iter()
                    .map(|(k, p)| (k.clone(), f64::from_bits(p.est_ms)))
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Sibling contexts of an application persisted in the memo: every
    /// context whose recorded `app` metadata matches `app`, except the
    /// `exclude` fingerprint (the caller's own context), as
    /// `(fabric_mhz, key → est_ms)` pairs in deterministic (fingerprint)
    /// order. This is what board-axis warm starts scale by the
    /// fabric-clock ratio when the sibling board was swept in an
    /// *earlier run* rather than earlier in the same call.
    pub fn sibling_points_ms(&self, app: &str, exclude: u64) -> Vec<(f64, Vec<(String, f64)>)> {
        self.contexts
            .iter()
            .filter(|(fp, c)| **fp != exclude && c.app == app)
            .map(|(_, c)| {
                let pts: Vec<(String, f64)> = c
                    .points
                    .iter()
                    .map(|(k, p)| (k.clone(), f64::from_bits(p.est_ms)))
                    .collect();
                (c.fabric_mhz, pts)
            })
            .collect()
    }

    /// Serialize to the memo JSON document.
    pub fn to_json(&self) -> String {
        let contexts: Vec<Value> = self
            .contexts
            .iter()
            .map(|(fp, c)| {
                let points: Vec<Value> = c
                    .points
                    .iter()
                    .map(|(k, p)| {
                        obj(vec![
                            ("key", k.as_str().into()),
                            ("est_ms", p.est_ms.into()),
                            ("energy_j", p.energy_j.into()),
                            ("edp", p.edp.into()),
                            ("fabric_util", p.fabric_util.into()),
                        ])
                    })
                    .collect();
                let frontier: Vec<Value> = c
                    .frontier()
                    .into_iter()
                    .map(|(m, e)| obj(vec![("est_ms", m.into()), ("energy_j", e.into())]))
                    .collect();
                obj(vec![
                    ("fp", format!("{fp:016x}").into()),
                    ("app", c.app.as_str().into()),
                    ("board", c.board.as_str().into()),
                    ("part", c.part.as_str().into()),
                    ("fabric_mhz", c.fabric_mhz.into()),
                    ("points", arr(points)),
                    ("frontier", arr(frontier)),
                ])
            })
            .collect();
        obj(vec![
            ("version", MEMO_SCHEMA_VERSION.into()),
            ("estimator", env!("CARGO_PKG_VERSION").into()),
            ("contexts", arr(contexts)),
        ])
        .to_json()
    }

    /// Parse a memo JSON document (version- and estimator-checked).
    pub fn from_json(text: &str) -> anyhow::Result<Self> {
        let v = parse(text).map_err(|e| anyhow::anyhow!("memo parse: {e}"))?;
        let version = v
            .get("version")
            .and_then(Value::as_i64)
            .ok_or_else(|| anyhow::anyhow!("memo file has no version"))?;
        anyhow::ensure!(
            version == MEMO_SCHEMA_VERSION,
            "memo schema v{version} != v{MEMO_SCHEMA_VERSION} — delete the memo file and re-sweep"
        );
        let estimator = v.get("estimator").and_then(Value::as_str).unwrap_or("");
        anyhow::ensure!(
            estimator == env!("CARGO_PKG_VERSION"),
            "memo written by estimator v{estimator}, this is v{} — delete the memo file and \
             re-sweep (results would not be comparable)",
            env!("CARGO_PKG_VERSION")
        );
        let mut memo = EvalMemo::new();
        let contexts = v
            .get("contexts")
            .and_then(Value::as_arr)
            .ok_or_else(|| anyhow::anyhow!("memo file has no contexts array"))?;
        for c in contexts {
            let fp_str = c
                .get("fp")
                .and_then(Value::as_str)
                .ok_or_else(|| anyhow::anyhow!("memo context has no fp"))?;
            let fp = u64::from_str_radix(fp_str, 16)
                .map_err(|_| anyhow::anyhow!("bad memo fingerprint '{fp_str}'"))?;
            let mut mc = MemoContext {
                app: c.get("app").and_then(Value::as_str).unwrap_or("").to_string(),
                board: c.get("board").and_then(Value::as_str).unwrap_or("").to_string(),
                part: c.get("part").and_then(Value::as_str).unwrap_or("").to_string(),
                fabric_mhz: c.get("fabric_mhz").and_then(Value::as_f64).unwrap_or(0.0),
                points: BTreeMap::new(),
            };
            for p in c.get("points").and_then(Value::as_arr).unwrap_or(&[]) {
                let key = p
                    .get("key")
                    .and_then(Value::as_str)
                    .ok_or_else(|| anyhow::anyhow!("memo point has no key"))?;
                let bits = |field: &str| -> anyhow::Result<u64> {
                    p.get(field)
                        .and_then(Value::as_i64)
                        .map(|i| i as u64)
                        .ok_or_else(|| anyhow::anyhow!("memo point '{key}' misses {field}"))
                };
                mc.points.insert(
                    key.to_string(),
                    MemoPoint {
                        est_ms: bits("est_ms")?,
                        energy_j: bits("energy_j")?,
                        edp: bits("edp")?,
                        fabric_util: bits("fabric_util")?,
                    },
                );
            }
            memo.contexts.insert(fp, mc);
        }
        Ok(memo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::matmul::Matmul;
    use crate::config::BoardConfig;
    use crate::dse::{DseSpace, Objective, OrderMode, SweepContext};
    use crate::hls::FpgaPart;

    fn fixture<'p>(
        program: &'p crate::coordinator::task::TaskProgram,
        board: &'p BoardConfig,
        space: &DseSpace,
    ) -> SweepContext<'p> {
        SweepContext::for_space(program, board, &FpgaPart::xc7z045(), space)
    }

    #[test]
    fn codesign_key_is_order_invariant() {
        let a = CoDesign::new("a")
            .with_accel("mxm64", 32)
            .with_accel("mxm64", 64)
            .with_smp("mxm64");
        let b = CoDesign::new("b")
            .with_accel("mxm64", 64)
            .with_accel("mxm64", 32)
            .with_smp("mxm64");
        assert_eq!(codesign_key(&a), codesign_key(&b));
        let c = CoDesign::new("c").with_accel("mxm64", 32).with_accel("mxm64", 32);
        assert_ne!(codesign_key(&a), codesign_key(&c));
    }

    #[test]
    fn fingerprint_separates_mismatchable_keys() {
        let board = BoardConfig::zynq706();
        let p = Matmul::new(256, 64).build_program(&board);
        let space = DseSpace::from_program(&p);
        let base = context_fingerprint(&fixture(&p, &board, &space));
        // Same inputs -> same fingerprint.
        assert_eq!(base, context_fingerprint(&fixture(&p, &board, &space)));
        // A different program (task cycle counts differ) must miss.
        let p2 = Matmul::new(512, 64).build_program(&board);
        assert_ne!(base, context_fingerprint(&fixture(&p2, &board, &space)));
        // A perturbed board must miss.
        let mut b2 = board.clone();
        b2.fabric_freq_mhz += 1.0;
        let p3 = Matmul::new(256, 64).build_program(&b2);
        assert_ne!(base, context_fingerprint(&fixture(&p3, &b2, &space)));
        // A different part must miss.
        let ctx_small = SweepContext::for_space(&p, &board, &FpgaPart::xc7z020(), &space);
        assert_ne!(base, context_fingerprint(&ctx_small));
        // The emulator block is explicitly NOT part of the key.
        let mut b3 = board.clone();
        b3.emu.seed ^= 1;
        let p4 = Matmul::new(256, 64).build_program(&b3);
        assert_eq!(base, context_fingerprint(&fixture(&p4, &b3, &space)));
    }

    #[test]
    fn memo_json_roundtrip_is_bit_exact() {
        let board = BoardConfig::zynq706();
        let p = Matmul::new(256, 64).build_program(&board);
        let space = DseSpace::from_program(&p);
        let ctx = fixture(&p, &board, &space);
        let fp = context_fingerprint(&ctx);
        let mut memo = EvalMemo::new();
        let (points, _) = ctx.explore_pruned(&space, Objective::Time, 2);
        for pt in &points {
            memo.record(&ctx, fp, &codesign_key(&pt.codesign), pt);
        }
        assert_eq!(memo.n_contexts(), 1);
        assert_eq!(memo.n_points(), points.len());
        let back = EvalMemo::from_json(&memo.to_json()).unwrap();
        for pt in &points {
            let hit = back.lookup(fp, &codesign_key(&pt.codesign)).unwrap();
            assert_eq!(hit.est_ms.to_bits(), pt.est_ms.to_bits());
            assert_eq!(hit.energy_j.to_bits(), pt.energy_j.to_bits());
            assert_eq!(hit.edp.to_bits(), pt.edp.to_bits());
            assert_eq!(hit.fabric_util.to_bits(), pt.fabric_util.to_bits());
        }
        assert!(back.lookup(fp ^ 1, "anything").is_none());
        assert!(!back.frontier(fp).is_empty());
        assert_eq!(back.points_ms(fp).len(), points.len());
    }

    #[test]
    fn memo_rejects_foreign_versions() {
        assert!(EvalMemo::from_json("{\"version\": 999, \"contexts\": []}").is_err());
        assert!(EvalMemo::from_json("{\"contexts\": []}").is_err());
        let wrong_estimator = format!(
            "{{\"version\": {MEMO_SCHEMA_VERSION}, \"estimator\": \"0.0.0\", \"contexts\": []}}"
        );
        assert!(EvalMemo::from_json(&wrong_estimator).is_err());
        assert!(EvalMemo::from_json("not json").is_err());
    }

    #[test]
    fn load_or_new_handles_missing_files() {
        let dir = std::env::temp_dir().join("zynq_warm_memo_t");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("memo.json");
        std::fs::remove_file(&path).ok();
        let memo = EvalMemo::load_or_new(&path).unwrap();
        assert_eq!(memo.n_points(), 0);
        memo.save(&path).unwrap();
        assert!(EvalMemo::load_or_new(&path).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn warm_sweep_skips_memo_hits_and_stays_exact() {
        let board = BoardConfig::zynq706();
        let p = Matmul::new(256, 64).build_program(&board);
        let space = DseSpace::from_program(&p).with_mixed();
        let ctx = fixture(&p, &board, &space);
        let mut memo = EvalMemo::new();
        let (cold, cold_stats) = ctx.explore_pruned(&space, Objective::Time, 2);
        let (first, first_stats) =
            ctx.explore_warm(&space, &mut memo, Objective::Time, 2, OrderMode::Ranked);
        assert_eq!(first_stats.memo_hits, 0);
        assert!(first_stats.evaluated > 0);
        // Exactness vs the cold pruned sweep: best + Pareto front.
        assert_eq!(
            cold[0].est_ms.to_bits(),
            first[0].est_ms.to_bits(),
            "warm best diverged ({} vs {})",
            cold[0].codesign.name,
            first[0].codesign.name
        );
        assert_eq!(
            super::super::pareto_front_coords(&cold),
            super::super::pareto_front_coords(&first)
        );
        assert!(cold_stats.evaluated > 0);
        // Second sweep over the identical space: zero evaluations, every
        // point served from the memo, ranking bit-identical.
        let (second, second_stats) =
            ctx.explore_warm(&space, &mut memo, Objective::Time, 2, OrderMode::Ranked);
        assert_eq!(second_stats.evaluated, 0, "{second_stats:?}");
        assert_eq!(second_stats.memo_hits as usize, first.len());
        assert_eq!(second.len(), first.len());
        for (a, b) in second.iter().zip(&first) {
            assert_eq!(a.codesign.name, b.codesign.name);
            assert_eq!(a.est_ms.to_bits(), b.est_ms.to_bits());
            assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
        }
    }
}
